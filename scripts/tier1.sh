#!/usr/bin/env bash
# Tier-1 gate + perf tables in one command:
#   ./scripts/tier1.sh [--fast] [extra pytest args]
#
# Default: the ROADMAP tier-1 test command, then the kernel (k),
# custom-VJP pair (kl, attn, ssd), ensemble/epoch-driver (e),
# grouped-client-training (c) and client-axis sharding (s) benchmark
# tables — printed as CSV and written as the machine-readable
# BENCH_PR5.json trajectory artifact (benchmarks/run.py --json; CI
# uploads it and benchmarks/check_regression.py gates PRs against the
# committed previous-PR baseline).
#
# --fast: tight-time-budget gate — skips tests marked `slow` (the long
# grouped-vs-python equivalence sweeps, see tests/conftest.py) and the
# benchmark tables. NOTE: because the tables are skipped, --fast does
# NOT emit BENCH_PR5.json; CI's bench job calls benchmarks/run.py --json
# directly instead.
#
# Exit code: nonzero iff any step fails. `set -e` aborts on the first
# failing command with its code, and the explicit final `exit` makes the
# propagation unconditional even for CI shells without pipefail/errexit
# heritage in the invoking environment.
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${1:-}" == "--fast" ]]; then
  shift
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m pytest -x -q -m "not slow" "$@"
  exit 0
fi

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python benchmarks/run.py --only k,kl,attn,ssd,e,c,s --json BENCH_PR5.json
exit 0
