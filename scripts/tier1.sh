#!/usr/bin/env bash
# Tier-1 gate + perf tables in one command:
#   ./scripts/tier1.sh [--fast] [extra pytest args]
#
# Default: the ROADMAP tier-1 test command, then the kernel (k),
# ensemble/epoch-driver (e) and grouped-client-training (c) benchmark
# tables so the perf trajectory is captured alongside every
# verification run.
#
# --fast: tight-time-budget gate — skips tests marked `slow` (the long
# grouped-vs-python equivalence sweeps, see tests/conftest.py) and the
# benchmark tables.
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${1:-}" == "--fast" ]]; then
  shift
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m pytest -x -q -m "not slow" "$@"
  exit 0
fi

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python benchmarks/run.py --only k,e,c
