#!/usr/bin/env bash
# Tier-1 gate + perf tables in one command:
#   ./scripts/tier1.sh [extra pytest args]
# Runs the ROADMAP tier-1 test command, then the kernel (k) and
# ensemble/epoch-driver (e) benchmark tables so the perf trajectory is
# captured alongside every verification run.
set -euo pipefail
cd "$(dirname "$0")/.."

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python benchmarks/run.py --only k,e
