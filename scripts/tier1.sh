#!/usr/bin/env bash
# Tier-1 gate + perf tables in one command:
#   ./scripts/tier1.sh [--fast|--chaos] [extra pytest args]
#
# Default: the ROADMAP tier-1 test command, then the kernel (k),
# custom-VJP pair (kl, attn, ssd), ensemble/epoch-driver (e),
# grouped-client-training (c), client-axis sharding (s),
# federation-axis scaling (m),
# robustness (r), backend-registry (bk) and serving-engine (serve)
# benchmark tables — printed
# as CSV and written as the machine-readable BENCH_PR10.json trajectory
# artifact (benchmarks/run.py --json; CI uploads it and
# benchmarks/check_regression.py gates PRs against the committed
# previous-PR baseline).
#
# --fast: tight-time-budget gate — skips tests marked `slow` (the long
# grouped-vs-python equivalence sweeps, see tests/conftest.py) and the
# benchmark tables. NOTE: because the tables are skipped, --fast does
# NOT emit BENCH_PR10.json; CI's bench job calls benchmarks/run.py --json
# directly instead.
#
# --chaos: the fault-injection matrix (DESIGN.md §10) — reruns the
# env-parameterized tests of tests/test_faults.py for every fault kind x
# admission policy under 8 forced host devices, so the quarantine masks
# are exercised through the genuinely-sharded psum teacher. Mirrors
# CI's `chaos` job (one matrix cell per job there; the whole grid here).
#
# Exit code: nonzero iff any step fails. `set -e` aborts on the first
# failing command with its code, and the explicit final `exit` makes the
# propagation unconditional even for CI shells without pipefail/errexit
# heritage in the invoking environment.
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${1:-}" == "--fast" ]]; then
  shift
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m pytest -x -q -m "not slow" "$@"
  exit 0
fi

if [[ "${1:-}" == "--chaos" ]]; then
  shift
  for kind in drop delay nan inf noise signflip; do
    for policy in quarantine strict; do
      echo "=== chaos: CHAOS_KIND=$kind CHAOS_POLICY=$policy ==="
      CHAOS_KIND=$kind CHAOS_POLICY=$policy \
        XLA_FLAGS="--xla_force_host_platform_device_count=8" \
        PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
        python -m pytest -x -q tests/test_faults.py \
          -k "matrix or removal or sharded or strict_policy" "$@"
    done
  done
  exit 0
fi

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python benchmarks/run.py --only k,kl,attn,ssd,e,c,s,r,bk,serve,m \
    --json BENCH_PR10.json
exit 0
