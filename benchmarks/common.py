"""Shared benchmark machinery: scaled experiment configs, federation cache,
method dispatch. One benchmark per paper table lives in run.py."""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.configs.paper_cifar import DenseExperimentConfig
from repro.core import evaluate, train_dense_server
from repro.data import make_classification_data
from repro.fl import (CommLedger, build_federation, fed_adi, fed_dafl,
                      fed_df, fedavg)


def time_call(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall seconds of fn(*args) after warmup calls.

    The warmup absorbs jit compilation (a cold call is mostly compile
    time, which the k/e tables must not report as runtime);
    block_until_ready forces async dispatch to finish before the clock
    stops. Returns the median of `iters` timed calls."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def time_ab(fa, a_args, fb, b_args, *, warmup: int = 3,
            iters: int = 21) -> tuple[float, float]:
    """Interleaved A/B timing: one A call then one B call per rep, median
    per side. On a noisy shared host, timing A's reps back-to-back and
    then B's lets a slow system phase land entirely on one side and skew
    the ratio; alternating exposes both sides to the same noise."""
    for _ in range(warmup):
        jax.block_until_ready(fa(*a_args))
        jax.block_until_ready(fb(*b_args))
    tsa, tsb = [], []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fa(*a_args))
        tsa.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fb(*b_args))
        tsb.append(time.perf_counter() - t0)
    return float(np.median(tsa)), float(np.median(tsb))


def base_cfg(full: bool) -> DenseExperimentConfig:
    """CPU-scaled analogue of the paper's §3.1.4 setting (DESIGN.md §2:
    relative claims, not absolute CIFAR numbers)."""
    if full:
        return DenseExperimentConfig(
            n_clients=5, alpha=0.5, local_epochs=12, batch_size=64,
            num_classes=10, image_size=16, in_ch=3, train_per_class=96,
            test_per_class=32, client_kinds=("cnn1",) * 5,
            global_kind="cnn1", width=0.5, nz=64, t_g=6, epochs=70,
            synth_batch=64, s_steps=6)
    return DenseExperimentConfig(
        n_clients=3, alpha=0.5, local_epochs=6, batch_size=64,
        num_classes=6, image_size=16, in_ch=3, train_per_class=48,
        test_per_class=16, client_kinds=("cnn1",) * 3, global_kind="cnn1",
        width=0.5, nz=32, t_g=4, epochs=25, synth_batch=64, s_steps=4)


_DATA_CACHE: dict = {}
_FED_CACHE: dict = {}


def get_data(scfg, seed=0):
    k = (scfg.num_classes, scfg.image_size, scfg.in_ch,
         scfg.train_per_class, scfg.test_per_class, seed)
    if k not in _DATA_CACHE:
        _DATA_CACHE[k] = make_classification_data(
            seed, num_classes=scfg.num_classes, size=scfg.image_size,
            ch=scfg.in_ch, train_per_class=scfg.train_per_class,
            test_per_class=scfg.test_per_class)
    return _DATA_CACHE[k]


def get_federation(scfg, seed=0):
    k = (scfg.n_clients, scfg.alpha, scfg.client_kinds, scfg.local_epochs,
         scfg.use_ldam, scfg.width, scfg.num_classes, scfg.image_size, seed,
         # fault/admission knobs change who survives the upload boundary
         getattr(scfg, "fault_plan", ()), getattr(scfg, "dropout_frac", 0.0),
         getattr(scfg, "fault_seed", 0), getattr(scfg, "upload_policy", ""),
         getattr(scfg, "quorum", 0.5), getattr(scfg, "norm_screen", 0.0))
    if k not in _FED_CACHE:
        data = get_data(scfg, seed)
        ledger = CommLedger()
        clients, _ = build_federation(jax.random.PRNGKey(seed), scfg, data,
                                      ledger=ledger, seed=seed)
        _FED_CACHE[k] = (data, clients, ledger)
    return _FED_CACHE[k]


def run_method(method: str, scfg, seed=0, **dense_kw):
    """-> (test_acc, seconds). Methods: fedavg feddf feddafl fedadi dense."""
    data, clients, _ = get_federation(scfg, seed)
    xt, yt = data["test"]
    key = jax.random.PRNGKey(100 + seed)
    t0 = time.time()
    if method == "fedavg":
        params = fedavg(clients)
        spec = clients[0].spec
    elif method == "feddf":
        params, spec = fed_df(key, clients, scfg)
    elif method == "feddafl":
        params, spec = fed_dafl(key, clients, scfg)
    elif method == "fedadi":
        params, spec = fed_adi(key, clients, scfg)
    elif method == "dense":
        params, _, _ = train_dense_server(key, clients, scfg, **dense_kw)
        spec = dataclasses.replace(
            clients[0].spec, kind=scfg.global_kind)
    else:
        raise ValueError(method)
    dt = time.time() - t0
    return evaluate(params, spec, xt, yt), dt


def ensemble_acc(scfg, seed=0):
    """Distillation ceiling: accuracy of the averaged-logit ensemble
    (grouped-vmap fast path)."""
    import jax.numpy as jnp
    from repro.core import grouped_ensemble_logits, stack_grouped
    data, clients, _ = get_federation(scfg, seed)
    xt, yt = data["test"]
    gspecs, gparams = stack_grouped(clients)
    f = jax.jit(lambda gp, x: grouped_ensemble_logits(gspecs, gp, x))
    pred = []
    for i in range(0, len(yt), 256):
        pred.append(np.argmax(np.asarray(
            f(gparams, jnp.asarray(xt[i:i + 256]))), -1))
    return float((np.concatenate(pred) == yt).mean())


RECORDS: list[dict] = []


def emit(name: str, seconds: float, derived: str):
    """CSV contract: name,us_per_call,derived. Every record is also
    collected in RECORDS so run.py --json can write the machine-readable
    trajectory file (BENCH_PR8.json)."""
    RECORDS.append({"name": name, "us_per_call": round(seconds * 1e6, 1),
                    "derived": derived})
    print(f"{name},{seconds * 1e6:.0f},{derived}", flush=True)


def _series_key(name: str) -> str:
    """Trajectory-diffable series id: the record name minus a trailing
    size parameter (/m8, /alpha0.5, /rounds2 ...), so each series pools
    only directly comparable variants — looped vs grouped vs sharded
    stay separate instead of being mixed into one meaningless median."""
    import re
    head, _, tail = name.rpartition("/")
    return head if head and re.fullmatch(
        r"(m|alpha|rounds|hetero|frac)[0-9.]+", tail) else name


def write_json(path: str) -> None:
    """Dump collected records + per-table AND per-series medians as one
    JSON document. Tables are the leading name component (k/e/c/s/...);
    medians are over nonzero us_per_call records (zero-cost rows are
    accuracy/speedup annotations, not timings). The per-series medians
    are the regression-trackable stats: a table median pools variants
    that are not comparable (e.g. c pools looped and grouped rows, so a
    grouped-engine regression could hide in it).
    benchmarks/check_regression.py consumes exactly these series medians
    to gate CI on cross-PR slowdowns."""
    import json
    import platform

    by_table: dict[str, list[float]] = {}
    by_series: dict[str, list[float]] = {}
    for r in RECORDS:
        by_table.setdefault(r["name"].split("/", 1)[0], []).append(
            r["us_per_call"])
        by_series.setdefault(_series_key(r["name"]), []).append(
            r["us_per_call"])

    def med(groups):
        out = {}
        for key, us in sorted(groups.items()):
            timed = [u for u in us if u > 0]
            out[key] = {"records": len(us),
                        "median_us": float(np.median(timed))
                        if timed else 0.0}
        return out

    payload = {"schema": "dense-bench-v1",
               "jax": jax.__version__,
               "backend": jax.default_backend(),
               "device_count": jax.device_count(),
               "python": platform.python_version(),
               "tables": med(by_table),
               "series": med(by_series),
               "records": RECORDS}
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"# wrote {path}: {len(RECORDS)} records, "
          f"tables={sorted(by_table)}", flush=True)
