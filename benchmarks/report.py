"""Convert a benchmarks/run.py CSV log into the EXPERIMENTS.md §Repro
markdown tables + claim-by-claim verdicts.

  python -m benchmarks.report /tmp/bench_full.log > repro.md
"""
from __future__ import annotations

import sys
from collections import defaultdict


def parse(path):
    rows = {}
    for line in open(path):
        line = line.strip()
        if not line or line.startswith("name,"):
            continue
        parts = line.split(",")
        if len(parts) < 3 or "acc=" not in parts[2]:
            continue
        name, us, derived = parts[0], parts[1], parts[2]
        acc = float(derived.split("acc=")[1].split(";")[0])
        rows[name] = (acc, float(us) / 1e6)
    return rows


def table(rows, prefix, row_keys, col_keys, rowfmt, colfmt):
    print(f"| {'':14s} | " + " | ".join(colfmt(c) for c in col_keys) + " |")
    print("|---" * (len(col_keys) + 1) + "|")
    for r in row_keys:
        cells = []
        for c in col_keys:
            k = f"{prefix}/{rowfmt(r)}/{colfmt(c)}"
            cells.append(f"{rows[k][0]:.3f}" if k in rows else "—")
        print(f"| {rowfmt(r):14s} | " + " | ".join(cells) + " |")
    print()


def main():
    rows = parse(sys.argv[1])
    methods = ["fedavg", "feddf", "feddafl", "fedadi", "dense",
               "ensemble_ceiling"]

    print("### T1 — accuracy across Dirichlet alpha (paper Table 1)\n")
    alphas = ["alpha0.1", "alpha0.3", "alpha0.5"]
    table(rows, "t1", methods, alphas, lambda m: m, lambda a: a)

    print("### T2 — heterogeneous client architectures (paper Table 2)\n")
    for k, v in sorted(rows.items()):
        if k.startswith("t2/"):
            print(f"- {k.split('/')[1]}: {v[0]:.3f}")
    print()

    print("### T3 — number of clients (paper Table 3)\n")
    for k, v in sorted(rows.items()):
        if k.startswith("t3/"):
            print(f"- {k[3:]}: {v[0]:.3f}")
    print()

    print("### T4 — DENSE + LDAM (paper Table 4)\n")
    for k, v in sorted(rows.items()):
        if k.startswith("t4/"):
            print(f"- {k[3:]}: {v[0]:.3f}")
    print()

    print("### T5 — multi-round extension (paper Table 5)\n")
    for k, v in sorted(rows.items()):
        if k.startswith("t5/"):
            print(f"- {k[3:]}: {v[0]:.3f}")
    print()

    print("### T6 — generator-loss ablation (paper Table 6)\n")
    for k, v in sorted(rows.items()):
        if k.startswith("t6/"):
            print(f"- {k[3:]}: {v[0]:.3f}")
    print()

    print("### F3 — local models vs one-shot FedAvg vs DENSE (paper Fig. 3)\n")
    for k, v in sorted(rows.items()):
        if k.startswith("f3/"):
            print(f"- {k[3:]}: {v[0]:.3f}")
    print()


if __name__ == "__main__":
    main()
