"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per the repo contract. Default is
a CI-sized budget; ``--full`` uses the budget behind EXPERIMENTS.md.

  T1  accuracy across alpha (non-IID severity) x methods     [Table 1]
  T2  heterogeneous client architectures                     [Table 2]
  T3  accuracy vs number of clients                          [Table 3]
  T4  DENSE + LDAM on skewed data                            [Table 4]
  T5  multi-round extension                                  [Table 5]
  T6  generator-loss ablation (CE / BN / div)                [Table 6]
  F3  one-shot FedAvg vs DENSE vs local models               [Figure 3]
  K   kernel microbenches (vs jnp oracle on CPU)             [kernels/]
  KL  distill-KL fwd / fwd+bwd, ref vs fused custom-VJP      [§Perf]
  ATTN flash-attention fwd / fwd+bwd, ref vs fused VJP pair  [§Perf]
  SSD  ssd chunked scan fwd / fwd+bwd, ref vs fused VJP pair [§Perf]
  E   ensemble forward looped vs grouped-vmap; epochs/sec    [§Perf]
  C   client local training looped vs grouped engine         [§Perf]
  S   client-axis mesh sharding vs single-device grouped     [§Perf]
  R   robustness: accuracy + clients/sec vs dropout_frac,
      quarantine admission, checkpoint/resume overhead       [§Robust]
  BK  backend execution-policy registry: registry-default vs
      autotuned blocks per kernel pair, resolution overhead  [§Perf]
  SERVE continuous-batching ServeEngine, paged vs dense, under
      a seeded Poisson arrival trace: tok/s + p50/p99        [§Serving]
  ROOF roofline summary from dry-run artifacts               [§Roofline]

``--json PATH`` additionally writes every emitted record plus per-table
medians as one machine-readable document (the BENCH_PR9.json perf
trajectory artifact; scripts/tier1.sh writes it, CI uploads it and
benchmarks/check_regression.py gates PRs on the per-series medians).
"""
from __future__ import annotations

import argparse
import dataclasses
import glob
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (base_cfg, emit, ensemble_acc, get_federation,
                               run_method, time_ab, time_call)


def t1_alpha_sweep(full: bool):
    alphas = (0.1, 0.3, 0.5) if full else (0.1, 0.5)
    methods = ("fedavg", "feddf", "feddafl", "fedadi", "dense")
    for alpha in alphas:
        scfg = dataclasses.replace(base_cfg(full), alpha=alpha)
        ens = ensemble_acc(scfg)
        emit(f"t1/ensemble_ceiling/alpha{alpha}", 0.0, f"acc={ens:.4f}")
        for m in methods:
            acc, dt = run_method(m, scfg)
            emit(f"t1/{m}/alpha{alpha}", dt, f"acc={acc:.4f}")


def t2_heterogeneous(full: bool):
    kinds = (("resnet18", "cnn1", "cnn2", "wrn16_1", "wrn40_1") if full
             else ("cnn1", "cnn2", "wrn16_1"))
    scfg = dataclasses.replace(
        base_cfg(full), client_kinds=kinds, n_clients=len(kinds),
        global_kind="wrn16_1" if not full else "resnet18")
    for m in ("feddf", "feddafl", "fedadi", "dense"):
        acc, dt = run_method(m, scfg)
        emit(f"t2/{m}/hetero{len(kinds)}", dt, f"acc={acc:.4f}")


def t3_num_clients(full: bool):
    ms = (5, 10, 20) if full else (3, 6)
    for n in ms:
        scfg = dataclasses.replace(base_cfg(full), n_clients=n,
                                   client_kinds=("cnn1",) * n)
        for m in (("fedavg", "feddf", "fedadi", "dense") if full
                  else ("fedavg", "dense")):
            acc, dt = run_method(m, scfg)
            emit(f"t3/{m}/m{n}", dt, f"acc={acc:.4f}")


def t4_ldam(full: bool):
    for alpha in ((0.1, 0.5) if full else (0.1,)):
        for ldam in (False, True):
            scfg = dataclasses.replace(base_cfg(full), alpha=alpha,
                                       use_ldam=ldam)
            acc, dt = run_method("dense", scfg)
            name = "dense+ldam" if ldam else "dense"
            emit(f"t4/{name}/alpha{alpha}", dt, f"acc={acc:.4f}")


def t5_multiround(full: bool):
    from repro.core import evaluate
    from repro.data import make_classification_data
    from repro.fl import dense_multi_round
    rounds = (1, 2, 3) if full else (1, 2)
    scfg = dataclasses.replace(base_cfg(full),
                               local_epochs=8 if full else 4)
    data = make_classification_data(0, num_classes=scfg.num_classes,
                                    size=scfg.image_size, ch=scfg.in_ch,
                                    train_per_class=scfg.train_per_class,
                                    test_per_class=scfg.test_per_class)
    xt, yt = data["test"]
    for tc in rounds:
        t0 = time.time()
        gp, spec, _ = dense_multi_round(jax.random.PRNGKey(0), scfg, data,
                                        rounds=tc)
        acc = evaluate(gp, spec, xt, yt)
        emit(f"t5/dense/rounds{tc}", time.time() - t0, f"acc={acc:.4f}")


def t6_ablation(full: bool):
    from repro.core import evaluate, train_dense_server
    scfg = base_cfg(full)
    data, clients, _ = get_federation(scfg)
    xt, yt = data["test"]
    variants = {"dense": {}, "w_ce_only": {"use_bn": False, "use_div": False},
                "wo_bn": {"use_bn": False}, "wo_div": {"use_div": False}}
    for name, kw in variants.items():
        t0 = time.time()
        stu, _, _ = train_dense_server(jax.random.PRNGKey(7), clients, scfg,
                                       **kw)
        acc = evaluate(stu, clients[0].spec, xt, yt)
        emit(f"t6/{name}", time.time() - t0, f"acc={acc:.4f}")


def f3_local_vs_global(full: bool):
    """Figure 3: DENSE above local models; one-shot FedAvg below them."""
    from repro.core import evaluate
    scfg = base_cfg(full)
    data, clients, _ = get_federation(scfg)
    xt, yt = data["test"]
    for i, c in enumerate(clients):
        acc = evaluate(c.params, c.spec, xt, yt)
        emit(f"f3/local{i}", 0.0, f"acc={acc:.4f}")
    for m in ("fedavg", "dense"):
        acc, dt = run_method(m, scfg)
        emit(f"f3/{m}", dt, f"acc={acc:.4f}")


def k_kernels(full: bool):
    """Kernel microbenches. time_call = warmup + median-of-N, so the
    reported µs is steady-state runtime, not compile time. Block shapes
    are pinned as explicit ExecPolicy overrides (configs/backend.py) so
    the series stays comparable across autotune-cache changes;
    kernel_vjp="autodiff" runs the bare forward kernels."""
    from repro.configs.backend import resolve_exec_policy
    from repro.kernels import ops, ref
    pol = resolve_exec_policy(None).replace(kernel_vjp="autodiff")
    key = jax.random.PRNGKey(0)
    B, Hq, Hkv, S, D = 1, 4, 2, 256, 64
    q = jax.random.normal(key, (B, Hq, S, D))
    k = jax.random.normal(key, (B, Hkv, S, D))
    v = jax.random.normal(key, (B, Hkv, S, D))
    p_fa = pol.override_blocks("flash_attention", block_q=64, block_k=64)
    dt = time_call(lambda: ops.flash_attention(q, k, v, policy=p_fa))
    o = ops.flash_attention(q, k, v, policy=p_fa)
    err = float(jnp.max(jnp.abs(o - ref.attention(q, k, v))))
    emit("k/flash_attention/256x64", dt, f"max_err={err:.2e};interpret=cpu")

    t_ = jax.random.normal(key, (64, 4096)) * 3
    s_ = jax.random.normal(jax.random.PRNGKey(1), (64, 4096)) * 3
    p_kl = pol.override_blocks("distill_kl", block_rows=32, block_v=1024)
    dt = time_call(lambda: ops.distill_kl(t_, s_, policy=p_kl))
    r = ops.distill_kl(t_, s_, policy=p_kl)
    err = float(jnp.max(jnp.abs(r - ref.distill_kl(t_, s_))))
    emit("k/distill_kl/64x4096", dt, f"max_err={err:.2e};interpret=cpu")

    x = jax.random.normal(key, (1, 256, 4, 32))
    dt_in = jax.nn.softplus(jax.random.normal(key, (1, 256, 4)))
    a = -jnp.exp(jax.random.normal(key, (4,)) * 0.3)
    b = jax.random.normal(key, (1, 256, 1, 32)) * 0.3
    c = jax.random.normal(key, (1, 256, 1, 32)) * 0.3
    p_ssd = pol.override_blocks("ssd_scan", chunk=64)
    dt = time_call(lambda: ops.ssd_scan(x, dt_in, a, b, c, policy=p_ssd))
    y, _ = ops.ssd_scan(x, dt_in, a, b, c, policy=p_ssd)
    y2, _ = ref.ssd(x, dt_in, a, b, c)
    err = float(jnp.max(jnp.abs(y - y2)))
    emit("k/ssd_scan/256x4x32", dt, f"max_err={err:.2e};interpret=cpu")


def kl_distill(full: bool):
    """KL: the stage-2 distillation loss, forward and forward+backward,
    ref (materialized jnp autodiff) vs the fused custom-VJP Pallas pair
    (kernels/distill_kl, DESIGN.md §9). On this CPU host the kernels run
    in interpret mode, so the µs columns measure the interpreter, not the
    Mosaic lowering — the trackable claims are the grad-equivalence error
    and the analytic peak-HBM residual bytes, which are backend-free."""
    from repro.configs.backend import resolve_exec_policy
    from repro.kernels import ops, ref
    R, V = 64, 4096
    br, bv = 32, 1024
    pol = resolve_exec_policy(None).override_blocks(
        "distill_kl", block_rows=br, block_v=bv)
    t = jax.random.normal(jax.random.PRNGKey(0), (R, V)) * 3
    s = jax.random.normal(jax.random.PRNGKey(1), (R, V)) * 3
    g = jnp.ones((R,), jnp.float32) / R
    iters = 5 if full else 3

    f_ref = jax.jit(ref.distill_kl)
    f_fus = jax.jit(lambda a, b: ops.distill_kl(a, b, policy=pol))

    def fwdbwd(fwd):
        def run(a, b):
            out, pull = jax.vjp(fwd, a, b)
            return out, pull(g)
        return jax.jit(run)

    fb_ref = fwdbwd(ref.distill_kl)
    fb_fus = fwdbwd(lambda a, b: ops.distill_kl(a, b, policy=pol))

    err_f = float(jnp.max(jnp.abs(f_fus(t, s) - f_ref(t, s))))
    (_, (dt_r, ds_r)), (_, (dt_k, ds_k)) = fb_ref(t, s), fb_fus(t, s)
    err_b = max(float(jnp.max(jnp.abs(dt_k - dt_r))),
                float(jnp.max(jnp.abs(ds_k - ds_r))))

    shape = f"{R}x{V}"
    for name, fn in (("fwd/ref", f_ref), ("fwd/fused", f_fus),
                     ("fwdbwd/ref", fb_ref), ("fwdbwd/fused", fb_fus)):
        dt = time_call(fn, t, s, warmup=1, iters=iters)
        err = err_f if name.startswith("fwd/") else err_b
        emit(f"kl/{name}/{shape}", dt, f"max_err={err:.2e};interpret=cpu")

    # analytic residual bytes saved fwd->bwd (what HBM must hold between
    # the passes): ref keeps two (R, V) f32 log-softmaxes; fused folds
    # its five online accumulators into three f32 rows — lse_t, lse_s,
    # kl (distill_kl._vjp_fwd; inputs are alive in both cases)
    def residuals(r, v):
        return 2 * 4 * r * v, 3 * 4 * r
    rb, fb = residuals(R, V)
    rb_p, fb_p = residuals(4096, 262144)
    emit(f"kl/residual_bytes/{shape}", 0.0,
         (f"ref={rb};fused={fb};ratio={rb / fb:.0f}x;"
          f"paper_scale_4096x262144:ref={rb_p};fused={fb_p}"))


def attn_flash(full: bool):
    """ATTN: blockwise attention forward and forward+backward, ref
    (materialized XLA softmax + autodiff) vs the streaming custom-VJP
    Pallas pair (kernels/flash_attention, DESIGN.md §9). Like the kl
    table, the CPU µs columns measure the interpreter — the trackable
    claims are grad-equivalence error and the analytic fwd→bwd residual
    bytes, which are backend-free."""
    from repro.configs.backend import resolve_exec_policy
    from repro.kernels import ops, ref
    B, Hq, Hkv, S, D = 1, 4, 2, 256, 64
    bq = bk = 64
    pol = resolve_exec_policy(None).replace(
        kernel_vjp="fused").override_blocks(
            "flash_attention", block_q=bq, block_k=bk)
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (B, Hq, S, D))
    k = jax.random.normal(ks[1], (B, Hkv, S, D))
    v = jax.random.normal(ks[2], (B, Hkv, S, D))
    g = jax.random.normal(ks[3], (B, Hq, S, D))
    iters = 5 if full else 3

    f_ref = jax.jit(lambda a, b, c: ref.attention(a, b, c))
    f_fus = jax.jit(lambda a, b, c: ops.flash_attention(a, b, c,
                                                        policy=pol))

    def fwdbwd(fwd):
        def run(a, b, c):
            out, pull = jax.vjp(fwd, a, b, c)
            return out, pull(g)
        return jax.jit(run)

    fb_ref = fwdbwd(lambda a, b, c: ref.attention(a, b, c))
    fb_fus = fwdbwd(lambda a, b, c: ops.flash_attention(a, b, c,
                                                        policy=pol))

    err_f = float(jnp.max(jnp.abs(f_fus(q, k, v) - f_ref(q, k, v))))
    (_, gr), (_, gk) = fb_ref(q, k, v), fb_fus(q, k, v)
    err_b = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(gk, gr))

    shape = f"{S}x{D}"
    for name, fn in (("fwd/ref", f_ref), ("fwd/fused", f_fus),
                     ("fwdbwd/ref", fb_ref), ("fwdbwd/fused", fb_fus)):
        dt = time_call(fn, q, k, v, warmup=1, iters=iters)
        err = err_f if name.startswith("fwd/") else err_b
        emit(f"attn/{name}/{shape}", dt, f"max_err={err:.2e};interpret=cpu")

    # analytic residual bytes fwd->bwd: ref/autodiff keeps the (B,Hq,S,S)
    # f32 probability matrix alive between the passes; the fused pair
    # keeps only the f32 output + per-row lse (flash_attention._vjp_fwd;
    # inputs are alive in both cases)
    def residuals(b_, h_, s_, d_):
        return 4 * b_ * h_ * s_ * s_, 4 * b_ * h_ * s_ * (d_ + 1)
    rb, fb = residuals(B, Hq, S, D)
    rb_p, fb_p = residuals(1, 32, 32768, 128)
    emit(f"attn/residual_bytes/{shape}", 0.0,
         (f"ref={rb};fused={fb};ratio={rb / fb:.0f}x;"
          f"prefill_32k_1x32x32768x128:ref={rb_p};fused={fb_p}"))


def ssd_table(full: bool):
    """SSD: the Mamba-2 chunked scan forward and forward+backward, ref
    (sequential jnp recurrence + autodiff) vs the reversed-recurrence
    custom-VJP Pallas pair (kernels/ssd_scan, DESIGN.md §9). Same CPU
    caveat as attn/kl: µs measures the interpreter; grad error and
    residual bytes are the backend-free claims."""
    from repro.configs.backend import resolve_exec_policy
    from repro.kernels import ops, ref
    B, S, H, P, G, N = 1, 256, 4, 32, 1, 32
    cl = 64
    pol = resolve_exec_policy(None).replace(
        kernel_vjp="fused").override_blocks("ssd_scan", chunk=cl)
    ks = jax.random.split(jax.random.PRNGKey(0), 7)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt_in = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    b = jax.random.normal(ks[3], (B, S, G, N)) * 0.3
    c = jax.random.normal(ks[4], (B, S, G, N)) * 0.3
    gy = jax.random.normal(ks[5], (B, S, H, P))
    gs = jax.random.normal(ks[6], (B, H, P, N)) * 0.1
    iters = 5 if full else 3

    f_ref = jax.jit(lambda *ar: ref.ssd(*ar))
    f_fus = jax.jit(lambda *ar: ops.ssd_scan(*ar, policy=pol))

    def fwdbwd(fwd):
        def run(*ar):
            (y, st), pull = jax.vjp(fwd, *ar)
            return y, pull((gy, gs))
        return jax.jit(run)

    fb_ref = fwdbwd(lambda *ar: ref.ssd(*ar))
    fb_fus = fwdbwd(lambda *ar: ops.ssd_scan(*ar, policy=pol))

    args = (x, dt_in, a, b, c)
    (y1, s1), (y2, s2) = f_ref(*args), f_fus(*args)
    err_f = max(float(jnp.max(jnp.abs(y1 - y2))),
                float(jnp.max(jnp.abs(s1 - s2))))
    (_, gr), (_, gk) = fb_ref(*args), fb_fus(*args)
    err_b = max(float(jnp.max(jnp.abs(a_ - b_)))
                for a_, b_ in zip(gk, gr))

    shape = f"{S}x{H}x{P}"
    for name, fn in (("fwd/ref", f_ref), ("fwd/fused", f_fus),
                     ("fwdbwd/ref", fb_ref), ("fwdbwd/fused", fb_fus)):
        dt = time_call(fn, *args, warmup=1, iters=iters)
        err = err_f if name.startswith("fwd/") else err_b
        emit(f"ssd/{name}/{shape}", dt, f"max_err={err:.2e};interpret=cpu")

    # analytic residual bytes fwd->bwd: autodiff of the recurrence keeps
    # the full (B,S,H,P,N) f32 state history; the fused pair keeps one
    # carried state per CHUNK (ssd_scan._vjp_fwd) — ratio = chunk length
    def residuals(b_, s_, h_, p_, n_, cl_):
        return 4 * b_ * s_ * h_ * p_ * n_, \
            4 * b_ * h_ * (-(-s_ // cl_)) * p_ * n_
    rb, fb = residuals(B, S, H, P, N, cl)
    rb_p, fb_p = residuals(1, 32768, 64, 64, 128, 256)
    emit(f"ssd/residual_bytes/{shape}", 0.0,
         (f"ref={rb};fused={fb};ratio={rb / fb:.0f}x;"
          f"prefill_32k_1x32768x64x64x128:ref={rb_p};fused={fb_p}"))


def e_ensemble(full: bool):
    """E: the DENSE server hot paths. (a) ensemble-forward µs/call,
    unrolled loop vs grouped-vmap, m ∈ {5,10,20} homogeneous clients;
    (b) epochs/sec of train_dense_server for loop_mode python vs fused.
    Post-warmup medians (time_call); trained clients are unnecessary —
    random inits have identical cost."""
    from repro.core.ensemble import (Client, ensemble_logits,
                                     grouped_ensemble_logits, split_clients,
                                     stack_grouped)
    from repro.models.cnn import CNNSpec, cnn_init
    spec = CNNSpec(kind="cnn1", num_classes=10, in_ch=3, width=0.5,
                   image_size=16)
    # per-call latency at a serving-style microbatch — the regime where
    # the unrolled loop pays m× fixed conv cost and the fused grouped
    # path (one batched GEMM per layer) structurally wins; large batches
    # are conv-FLOP-bound and converge to the same floor for all paths
    b = 16
    x = jax.random.normal(jax.random.PRNGKey(0), (b, 16, 16, 3))
    for m in (5, 10, 20):
        clients = [Client(spec=spec,
                          params=cnn_init(jax.random.PRNGKey(i), spec))
                   for i in range(m)]
        specs, cparams = split_clients(clients)
        gspecs, gparams = stack_grouped(clients)
        f_loop = jax.jit(lambda cp, xb: ensemble_logits(specs, cp, xb))
        f_grp = jax.jit(
            lambda gp, xb: grouped_ensemble_logits(gspecs, gp, xb))
        t_loop, t_grp = time_ab(f_loop, (cparams, x), f_grp, (gparams, x))
        emit(f"e/ensemble_forward/looped/m{m}", t_loop, f"batch={b}")
        emit(f"e/ensemble_forward/grouped/m{m}", t_grp,
             f"batch={b};speedup={t_loop / t_grp:.2f}x")

    # epochs/sec of the two epoch drivers, steady state. Build the jitted
    # steps ONCE (train_dense_server rebuilds them per call, which would
    # make every timed call recompile and report compile time as runtime)
    # and time repeated passes threading the carry through, so donated
    # buffers stay valid and compile happens only in the warmup pass.
    from repro.core import generator as G
    from repro.core.dense import make_dense_steps
    n = 4
    scfg = dataclasses.replace(
        base_cfg(False), n_clients=n, client_kinds=("cnn1",) * n,
        num_classes=6, image_size=16, width=0.25, nz=16, t_g=2,
        synth_batch=32, s_steps=1, loop_chunk=4)
    cspec = CNNSpec(kind="cnn1", num_classes=scfg.num_classes, in_ch=3,
                    width=scfg.width, image_size=scfg.image_size)
    clients = [Client(spec=cspec,
                      params=cnn_init(jax.random.PRNGKey(i), cspec))
               for i in range(n)]
    (gen_step, student_step, g_opt, s_opt, gparams, _,
     epochs_step) = make_dense_steps(clients, cspec, scfg)
    key = jax.random.PRNGKey(0)
    k_gen, k_stu, key = jax.random.split(key, 3)
    gen_p0 = G.img_generator_init(k_gen, nz=scfg.nz,
                                  img_size=scfg.image_size, out_ch=3)
    stu_p0 = cnn_init(k_stu, cspec)
    keys = jax.random.split(key, scfg.loop_chunk)
    passes = 3 if not full else 8

    def python_pass(state):
        gen_p, g_state, stu_p, s_state = state
        b, nz = scfg.synth_batch, scfg.nz
        for ek in keys:
            kz, ky, _ = jax.random.split(ek, 3)
            z = jax.random.normal(kz, (b, nz))
            yl = jax.random.randint(ky, (b,), 0, scfg.num_classes)
            for _ in range(scfg.t_g):
                gen_p, g_state, gl, _ = gen_step(gen_p, g_state, stu_p,
                                                 gparams, z, yl)
            stu_p, s_state, dl = student_step(stu_p, s_state, gen_p,
                                              gparams, z)
        jax.block_until_ready(dl)
        return gen_p, g_state, stu_p, s_state

    def fused_pass(state):
        out = epochs_step(*state, gparams, keys)
        jax.block_until_ready(out[4]["dis_loss"])
        return out[:4]

    for mode, one_pass in (("python", python_pass), ("fused", fused_pass)):
        # fresh copies per mode: epochs_step donates its carry, which
        # would delete gen_p0/stu_p0 for any later use
        state = jax.tree.map(jnp.copy, (gen_p0, g_opt.init(gen_p0),
                                        stu_p0, s_opt.init(stu_p0)))
        state = one_pass(state)                 # warmup: compile
        ts = []
        for _ in range(passes):
            t0 = time.perf_counter()
            state = one_pass(state)
            ts.append(time.perf_counter() - t0)
        dt = float(np.median(ts))
        emit(f"e/epochs_per_sec/{mode}", dt,
             f"epochs={scfg.loop_chunk};eps={scfg.loop_chunk / dt:.2f}")


def c_client_training(full: bool):
    """C: the federation's local-update phase. Per-client python loop
    (one jitted step per minibatch, host-side slicing) vs the grouped
    engine (fl/federation: one fused scanned program per architecture
    group), m ∈ {5,10,20}, homogeneous cnn1 and 2-group cnn1/cnn2
    heterogeneous. Both sides run the IDENTICAL seeded schedule on
    ragged shards (n=40, batch=16 -> two full + one half batch per
    epoch); time_ab interleaves the passes; the grouped side re-stacks
    inits and rebuilds its batch plan every pass (that host work is part
    of the engine's cost). Sized at the CI-scale client spec the tier-1
    suite trains (image 8, width 0.25) — the per-step-fixed-cost /
    dispatch-dominated regime the grouped engine targets; at
    paper-scale widths on this 1-2-core CPU host both paths are
    conv-FLOP-bound and converge (an accelerator backend changes the
    regime — the backend registry, configs/backend.py, owns that flip). Reported derived values: µs per real
    optimizer step and whole-federation clients/sec."""
    from repro.data.pipeline import batches, build_batch_plan, pad_shards
    from repro.fl.client import make_grouped_local_update, make_local_step
    from repro.fl.federation import group_specs
    from repro.models.cnn import CNNSpec, cnn_init

    n_per, batch, epochs = 40, 16, 2
    steps_per_client = epochs * (-(-n_per // batch))
    rng = np.random.default_rng(0)

    def spec_of(kind):
        return CNNSpec(kind=kind, num_classes=6, in_ch=3, width=0.25,
                       image_size=8)

    for m in (5, 10, 20):
        for variant in ("homog", "hetero2"):
            kinds = ("cnn1",) * m if variant == "homog" else \
                tuple("cnn1" if i % 2 == 0 else "cnn2" for i in range(m))
            specs = [spec_of(k) for k in kinds]
            shards = [(rng.standard_normal((n_per, 8, 8, 3))
                       .astype(np.float32), rng.integers(0, 6, n_per))
                      for _ in range(m)]
            inits = [cnn_init(jax.random.PRNGKey(i), s)
                     for i, s in enumerate(specs)]
            groups = group_specs(specs)
            zeros_marg = jnp.zeros((6,))
            group_data = [(spec, idx, *pad_shards([shards[i] for i in idx]))
                          for spec, idx in groups]

            def looped_pass():
                # block on EVERY client's final loss: with async dispatch,
                # syncing only the last client would stop the clock while
                # earlier clients' chains are still in flight
                done = []
                for spec, idx in groups:
                    step, opt = make_local_step(spec, lr=0.01, momentum=0.9,
                                                use_ldam=False)
                    for i in idx:
                        p, st = inits[i], opt.init(inits[i])
                        for bx, by in batches(*shards[i], batch, seed=i,
                                              epochs=epochs):
                            p, st, loss = step(p, st, jnp.asarray(bx),
                                               jnp.asarray(by), zeros_marg)
                        done.append(loss)
                jax.block_until_ready(done)

            def grouped_pass():
                done = []
                for spec, idx, xs, ys in group_data:
                    run, opt = make_grouped_local_update(
                        spec, lr=0.01, momentum=0.9, use_ldam=False)
                    plan = build_batch_plan([n_per] * len(idx), batch,
                                            epochs=epochs,
                                            seeds=list(idx))
                    stacked0 = jax.tree.map(
                        lambda *a: jnp.stack(a), *[inits[i] for i in idx])
                    p, s, losses = run(stacked0, opt.init(stacked0),
                                       jnp.asarray(xs), jnp.asarray(ys),
                                       jnp.asarray(plan.idx),
                                       jnp.asarray(plan.mask),
                                       jnp.zeros((len(idx), 6)))
                    done.append(losses)
                jax.block_until_ready(done)

            t_loop, t_grp = time_ab(looped_pass, (), grouped_pass, (),
                                    warmup=2, iters=7 if not full else 15)
            total_steps = m * steps_per_client
            for name, t in (("looped", t_loop), ("grouped", t_grp)):
                emit(f"c/local_train/{name}/{variant}/m{m}",
                     t / total_steps,
                     f"clients_per_sec={m / t:.2f};steps={total_steps}")
            emit(f"c/local_train/speedup/{variant}/m{m}", 0.0,
                 f"grouped_over_looped={t_loop / t_grp:.2f}x")


def s_sharding(full: bool):
    """S: the client-axis mesh (fl/sharding). (a) grouped ensemble
    forward, single-device vs sharded-over-("clients","data"); (b) the
    grouped local-update scan, unplaced vs client-sharded placement.
    On a 1-device host the mesh is degenerate (clients axis = 1) and the
    table measures pure shard_map/placement overhead; run under
    XLA_FLAGS=--xla_force_host_platform_device_count=N (or an accelerator
    backend) for real-axis numbers — derived reports the axis size so
    the trajectory records which regime was measured."""
    from repro.core.ensemble import (Client, grouped_ensemble_logits,
                                     stack_grouped)
    from repro.data.pipeline import build_batch_plan, pad_shards
    from repro.fl.client import make_grouped_local_update
    from repro.fl.sharding import (client_axis_size, group_shardable,
                                   put_grouped, put_stacked)
    from repro.launch.mesh import make_client_mesh
    from repro.models.cnn import CNNSpec, cnn_init

    mesh = make_client_mesh()
    c = client_axis_size(mesh)
    spec = CNNSpec(kind="cnn1", num_classes=10, in_ch=3, width=0.5,
                   image_size=16)
    b = 16
    x = jax.random.normal(jax.random.PRNGKey(0), (b, 16, 16, 3))
    for m in (8, 16):
        clients = [Client(spec=spec,
                          params=cnn_init(jax.random.PRNGKey(i), spec))
                   for i in range(m)]
        gspecs, gparams = stack_grouped(clients)
        sharded = group_shardable(mesh, m)
        gp_sh = put_grouped(gspecs, gparams, mesh)
        f_one = jax.jit(lambda gp, xb: grouped_ensemble_logits(gspecs, gp,
                                                               xb))
        f_sh = jax.jit(lambda gp, xb: grouped_ensemble_logits(
            gspecs, gp, xb, mesh=mesh))
        t_one, t_sh = time_ab(f_one, (gparams, x), f_sh, (gp_sh, x))
        emit(f"s/ensemble_forward/single/m{m}", t_one, f"batch={b}")
        emit(f"s/ensemble_forward/sharded/m{m}", t_sh,
             (f"batch={b};clients_axis={c};sharded={sharded};"
              f"speedup={t_one / t_sh:.2f}x"))

    n_per, batch, epochs = 40, 16, 2
    rng = np.random.default_rng(0)
    tspec = CNNSpec(kind="cnn1", num_classes=6, in_ch=3, width=0.25,
                    image_size=8)
    for m in (8, 16):
        shards = [(rng.standard_normal((n_per, 8, 8, 3)).astype(np.float32),
                   rng.integers(0, 6, n_per)) for _ in range(m)]
        inits = [cnn_init(jax.random.PRNGKey(i), tspec) for i in range(m)]
        xs, ys = pad_shards(shards)
        plan = build_batch_plan([n_per] * m, batch, epochs=epochs,
                                seeds=list(range(m)))
        run, opt = make_grouped_local_update(tspec, lr=0.01, momentum=0.9,
                                             use_ldam=False)
        margins = jnp.zeros((m, 6))
        args0 = (jnp.asarray(xs), jnp.asarray(ys), jnp.asarray(plan.idx),
                 jnp.asarray(plan.mask), margins)
        sharded = group_shardable(mesh, m)
        args_sh = put_stacked(args0, mesh, m) if sharded else args0

        def one_pass(args):
            stacked0 = jax.tree.map(lambda *a: jnp.stack(a), *inits)
            state = opt.init(stacked0)
            if args is args_sh and sharded:
                stacked0, state = put_stacked((stacked0, state), mesh, m)
            p, s, losses = run(stacked0, state, *args)
            jax.block_until_ready(losses)

        t_one, t_sh = time_ab(one_pass, (args0,), one_pass, (args_sh,),
                              warmup=2, iters=7 if not full else 15)
        steps = m * epochs * (-(-n_per // batch))
        emit(f"s/local_train/single/m{m}", t_one / steps,
             f"clients_per_sec={m / t_one:.2f}")
        emit(f"s/local_train/sharded/m{m}", t_sh / steps,
             (f"clients_per_sec={m / t_sh:.2f};clients_axis={c};"
              f"sharded={sharded};speedup={t_one / t_sh:.2f}x"))


def bk_backend(full: bool):
    """BK: the backend execution-policy registry (configs/backend.py,
    DESIGN.md §11). Per kernel pair, forward µs at the registry-default
    block table vs the committed seed-cache autotuned blocks for a
    shape whose bucket the seed actually tuned (the 512-dim buckets,
    where the tuned choice differs from the table). Interpret-mode
    timings on this shared CPU host are jittery, so the default vs
    autotuned contrast is trajectory data, not a claim — the gateable
    series are each column against its own history. Plus the
    resolve_exec_policy overhead itself:
    cold (memos dropped, cache-file stat + profile build) and warm
    (memo hit) — warm is what every make_*_steps call pays."""
    from repro.configs import backend as B
    from repro.kernels import ops

    scfg = base_cfg(full)
    B.resolve_exec_policy(scfg)                     # prime the memo
    for variant, prep in (("cold", B.clear_caches), ("warm", lambda: None)):
        ts = []
        for _ in range(50):
            prep()
            t0 = time.perf_counter()
            B.resolve_exec_policy(scfg)
            ts.append(time.perf_counter() - t0)
        emit(f"bk/resolve/{variant}", float(np.median(ts)),
             f"iters=50;backend={B.detect_backend(scfg)}")

    pol = B.resolve_exec_policy(None).replace(kernel_vjp="autodiff")
    key = jax.random.PRNGKey(0)
    t_ = jax.random.normal(key, (512, 4096)) * 3
    s_ = jax.random.normal(jax.random.PRNGKey(1), (512, 4096)) * 3
    q = jax.random.normal(key, (1, 2, 512, 16))
    x = jax.random.normal(key, (1, 512, 2, 8))
    dt_in = jax.nn.softplus(jax.random.normal(key, (1, 512, 2)))
    a = -jnp.exp(jax.random.normal(key, (2,)) * 0.3)
    bm = jax.random.normal(key, (1, 512, 1, 8)) * 0.3
    cases = (
        ("distill_kl", (512, 4096),
         lambda p: ops.distill_kl(t_, s_, policy=p)),
        ("flash_attention", (512, 512),
         lambda p: ops.flash_attention(q, q, q, policy=p)),
        ("ssd_scan", (512,),
         lambda p: ops.ssd_scan(x, dt_in, a, bm, bm, policy=p)),
    )
    iters = 3 if full else 2
    for kernel, shape, call in cases:
        names = B.KERNEL_BLOCK_ARGS[kernel]
        default = pol.blocks_for(kernel)            # registry table
        tuned = B.autotune_blocks(kernel, shape, pol)  # seed-cache hit
        p_def = pol.override_blocks(kernel, **dict(zip(names, default)))
        p_tun = pol.override_blocks(kernel, **dict(zip(names, tuned)))
        t_def, t_tun = time_ab(call, (p_def,), call, (p_tun,),
                               warmup=1, iters=iters)
        sh = "x".join(str(d) for d in shape)
        emit(f"bk/{kernel}/default/{sh}", t_def, f"blocks={default}")
        emit(f"bk/{kernel}/autotuned/{sh}", t_tun,
             f"blocks={tuned};speedup={t_def / t_tun:.2f}x")


def serve_table(full: bool):
    """SERVE: request-level serving (launch/engine.py, DESIGN.md §12).
    Paged continuous batching vs the sequential dense reference under a
    seeded synthetic Poisson arrival trace, at two regimes: ``trickle``
    (arrivals spread out — continuous batching earns little) and
    ``burst`` (a queue forms at t=0 — the paged engine's fused decode
    step over all slots is the win). Arrival times are in scheduler
    steps, not wall-clock, so the trace is identical for both engines
    and across runs. Emits wall seconds per run; the derived column
    carries tok_per_sec and p50/p99 per-request latency (submit→done,
    so queueing counts). First-request latency includes jit warmup on
    both sides — trajectory data, same caveat as the BK table."""
    from repro.configs.base import get_smoke_config
    from repro.launch.engine import ServeEngine, engine_keys

    cfg = get_smoke_config("llama3.2-3b")
    gen = 16 if full else 8
    plens = (6, 10)                       # two jit buckets, ragged batch
    k_init, k_prompt, _ = engine_keys(0)
    from repro.models import transformer as T
    params = T.init_model(k_init, cfg)
    rng = np.random.default_rng(9)        # the seeded Poisson trace

    def drive(mode, n, rate, max_reqs):
        prompts = [np.asarray(jax.random.randint(
            jax.random.fold_in(k_prompt, i), (plens[i % 2],), 0,
            cfg.vocab_size), np.int32) for i in range(n)]
        arrive = np.floor(np.cumsum(
            rng.exponential(1.0 / rate, n))).astype(int) if rate > 0 \
            else np.zeros(n, int)
        eng = ServeEngine(cfg, params, mode=mode, max_reqs=max_reqs,
                          max_len=max(plens) + gen, seed=0)
        rids, i, step = [], 0, 0
        limit = int(arrive.max(initial=0)) + 4 * n * (gen + 2) + 50
        t0 = time.perf_counter()
        while i < n or any(eng.poll(r)["status"] != "done" for r in rids):
            while i < n and arrive[i] <= step:
                rids.append(eng.submit(prompts[i], max_new=gen))
                i += 1
            eng.step()
            step += 1
            if step > limit:
                raise RuntimeError("serve bench scheduler stuck")
        wall = time.perf_counter() - t0
        lat = np.asarray([eng.poll(r)["latency_s"] for r in rids])
        return wall, n * gen / wall, lat

    regimes = (("trickle", 4, 0.25, 2), ("burst", 8 if full else 6, 0.0, 4))
    for regime, n, rate, max_reqs in regimes:
        walls = {}
        for mode in ("paged", "dense"):
            # same rng state for both engines: re-seed per run so the
            # two modes see the identical arrival trace
            rng = np.random.default_rng(9)
            wall, tps, lat = drive(mode, n, rate, max_reqs)
            walls[mode] = wall
            emit(f"serve/{mode}/{regime}", wall,
                 (f"tok_per_sec={tps:.1f};"
                  f"p50_ms={np.percentile(lat, 50) * 1e3:.1f};"
                  f"p99_ms={np.percentile(lat, 99) * 1e3:.1f};"
                  f"reqs={n};gen={gen};slots={max_reqs}"))
        emit(f"serve/paged_vs_dense/{regime}", 0.0,
             f"speedup={walls['dense'] / walls['paged']:.2f}x")


def r_roofline(full: bool):
    """Summarize dry-run artifacts (run repro.launch.dryrun first)."""
    files = sorted(glob.glob(os.path.join(
        os.path.dirname(__file__), "..", "artifacts", "dryrun", "*.json")))
    if not files:
        emit("r/roofline", 0.0,
             "no_artifacts;run=python -m repro.launch.dryrun --all")
        return
    for f in files:
        rec = json.load(open(f))
        tag = f"r/{rec['arch']}/{rec['shape']}/{rec['mesh']}"
        if rec.get("status") != "ok":
            emit(tag, 0.0, f"status={rec.get('status')}")
            continue
        t = rec.get("roofline") or rec["roofline_raw"]
        emit(tag, rec.get("compile_s", 0.0),
             (f"bottleneck={rec['bottleneck']};"
              f"compute_s={t['compute_s']:.4f};"
              f"memory_s={t['memory_s']:.4f};"
              f"collective_s={t['collective_s']:.6f};"
              f"useful_ratio={rec.get('useful_flops_ratio', 0.0):.3f}"))


def r_robustness(full: bool):
    """Fault-tolerant one-shot round (DESIGN.md §10): DENSE accuracy and
    local-phase throughput as the per-round client dropout fraction
    grows under quarantine admission, plus the stage-2 checkpointing
    overhead and a kill+resume round trip."""
    import shutil
    import tempfile

    from repro.core.dense import train_dense_server
    from repro.data import make_classification_data
    from repro.fl import build_federation

    base = dataclasses.replace(
        base_cfg(full), n_clients=5, client_kinds=("cnn1",) * 5,
        quorum=0.2, fault_seed=1)
    fracs = (0.0, 0.1, 0.3, 0.5) if full else (0.0, 0.3, 0.5)
    for frac in fracs:
        scfg = dataclasses.replace(base, dropout_frac=frac)
        data, clients, _ = get_federation(scfg)
        # time the local phase + fault/admission boundary fresh (the
        # cached build above only warmed data + compilation)
        t0 = time.time()
        fresh, _ = build_federation(jax.random.PRNGKey(0), scfg, data,
                                    seed=0)
        t_build = time.time() - t0
        m = scfg.n_clients
        surv = int(getattr(fresh, "survivor_mask",
                           np.ones(m, bool)).sum())
        acc, dt = run_method("dense", scfg)
        emit(f"r/local_train/frac{frac}", t_build / m,
             f"clients_per_sec={m / t_build:.2f};survivors={surv}/{m}")
        emit(f"r/dense/frac{frac}", dt,
             f"acc={acc:.4f};survivors={surv}/{m}")

    # checkpointing overhead + kill/resume round trip (quarantine-free)
    data, clients, _ = get_federation(base)
    key = jax.random.PRNGKey(100)
    t0 = time.time()
    train_dense_server(key, clients, base)
    t_plain = time.time() - t0
    ckdir = tempfile.mkdtemp(prefix="dense_bench_ck_")
    try:
        every = max(2, base.epochs // 5)
        scfg_ck = dataclasses.replace(
            base, checkpoint_every=every,
            checkpoint_path=os.path.join(ckdir, "ck"))
        t0 = time.time()
        train_dense_server(key, clients, scfg_ck)
        t_ck = time.time() - t0
        emit("r/checkpoint_overhead", t_ck,
             (f"every={every};overhead={t_ck / t_plain:.3f}x;"
              f"plain_s={t_plain:.2f}"))
        # kill at ~60% of the run, resume from the last checkpoint
        shutil.rmtree(ckdir)
        os.makedirs(ckdir)
        stop = (base.epochs * 3) // 5
        t0 = time.time()
        train_dense_server(key, clients, scfg_ck,
                           _stop_after_epoch=stop)
        train_dense_server(key, clients, scfg_ck)
        t_resume = time.time() - t0
        emit("r/kill_resume", t_resume,
             (f"stop_epoch={stop};roundtrip_vs_plain="
              f"{t_resume / t_plain:.3f}x"))
    finally:
        shutil.rmtree(ckdir, ignore_errors=True)


def m_scaling(full: bool):
    """Federation-axis scaling (DESIGN.md §13): the m in {10,100,1000}
    curve behind the bucketed/chunked engine. Per m: local-phase
    clients/sec under quantile buckets + chunked group setup, padded-step
    waste per bucketing mode on a Dirichlet alpha=0.1 partition, host
    peak RSS, tree-vs-flat fedavg and the chunked ensemble teacher. A
    heterogeneous (cnn1+cnn2) point rides at the largest m to pin the
    multi-group path."""
    import resource

    from repro.configs.backend import resolve_exec_policy
    from repro.data.partition import dirichlet_partition
    from repro.data.pipeline import plan_step_waste
    from repro.core.ensemble import grouped_ensemble_logits
    from repro.fl import fedavg_stacked, train_clients_grouped
    from repro.models.cnn import CNNSpec

    spec_kw = dict(num_classes=4, in_ch=1, width=0.25, image_size=8)
    batch = 16

    def rss_mb():
        return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024

    def build(m, kinds, seed=0):
        rng = np.random.default_rng(seed)
        y = rng.integers(0, 4, max(8 * m, 2000))
        parts = dirichlet_partition(y, m, 0.1, seed=seed)
        sizes = [max(2, len(p)) for p in parts]
        shards = [(rng.standard_normal((n, 8, 8, 1)).astype(np.float32),
                   rng.integers(0, 4, n)) for n in sizes]
        specs = [CNNSpec(kind=kinds[i % len(kinds)], **spec_kw)
                 for i in range(m)]
        return specs, shards, sizes

    pol = resolve_exec_policy(SimpleNamespaceCfg())
    ms = (10, 100, 1000)
    for m in ms:
        specs, shards, sizes = build(m, ("cnn1",))
        for mode in ("off", "pow2", "quantile"):
            w = plan_step_waste(sizes, batch, mode)
            emit(f"m/plan_waste_{mode}/m{m}", 0.0,
                 f"waste={w:.4f};batch={batch}")
        keys = list(jax.random.split(jax.random.PRNGKey(1), m))
        t0 = time.time()
        clients = train_clients_grouped(
            specs, shards, epochs=1, lr=0.05, momentum=0.9,
            batch_size=batch, use_ldam=False, num_classes=4,
            seeds=list(range(m)), init_keys=keys, policy=pol)
        dt = time.time() - t0
        emit(f"m/local_train/m{m}", dt / m,
             f"clients_per_sec={m / dt:.2f};rss_mb={rss_mb():.0f}")
        gspecs, gparams = clients.grouped
        n_data = [c.n_data for c in clients]
        t_flat = time_call(lambda: fedavg_stacked(gparams[0], n_data))
        t_tree = time_call(lambda: fedavg_stacked(
            gparams[0], n_data, mode="tree", branch=pol.fedavg_branch))
        emit(f"m/fedavg_tree/m{m}", t_tree,
             f"branch={pol.fedavg_branch};flat_s={t_flat:.4f};"
             f"speedup={t_flat / t_tree:.2f}x")
        x = jnp.asarray(np.random.default_rng(2).standard_normal(
            (batch, 8, 8, 1)).astype(np.float32))
        t_full = time_call(lambda: grouped_ensemble_logits(
            gspecs, gparams, x))
        t_chunk = time_call(lambda: grouped_ensemble_logits(
            gspecs, gparams, x, chunk=pol.teacher_chunk))
        emit(f"m/teacher_chunked/m{m}", t_chunk,
             f"chunk={pol.teacher_chunk};full_s={t_full:.4f};"
             f"rss_mb={rss_mb():.0f}")

    # heterogeneous point at the curve's top: multi-group bucketing
    m = ms[-1] if full else ms[-2]
    specs, shards, _ = build(m, ("cnn1", "cnn2"), seed=3)
    keys = list(jax.random.split(jax.random.PRNGKey(4), m))
    t0 = time.time()
    train_clients_grouped(
        specs, shards, epochs=1, lr=0.05, momentum=0.9, batch_size=batch,
        use_ldam=False, num_classes=4, seeds=list(range(m)),
        init_keys=keys, policy=pol)
    dt = time.time() - t0
    emit(f"m/local_train_hetero/m{m}", dt / m,
         f"clients_per_sec={m / dt:.2f};groups=2;rss_mb={rss_mb():.0f}")


class SimpleNamespaceCfg:
    """Minimal scfg for the scale table: every federation-scale knob on,
    everything else at registry defaults."""
    plan_bucketing = "quantile"
    stack_chunk = 64
    fedavg_mode = "tree"
    fedavg_branch = 8
    teacher_chunk = 64


TABLES = {"t1": t1_alpha_sweep, "t2": t2_heterogeneous, "t3": t3_num_clients,
          "t4": t4_ldam, "t5": t5_multiround, "t6": t6_ablation,
          "f3": f3_local_vs_global, "k": k_kernels, "kl": kl_distill,
          "attn": attn_flash, "ssd": ssd_table, "e": e_ensemble,
          "c": c_client_training, "s": s_sharding, "r": r_robustness,
          "bk": bk_backend, "serve": serve_table, "roof": r_roofline,
          "m": m_scaling}


def main() -> None:
    from benchmarks.common import write_json
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="EXPERIMENTS.md budget (slow)")
    ap.add_argument("--only", default=None,
                    help="comma list of tables, e.g. t1,t6,k")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write records + per-table medians as JSON "
                         "(the BENCH_PR9.json trajectory artifact)")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(TABLES)
    print("name,us_per_call,derived", flush=True)
    for n in names:
        TABLES[n](args.full)
    if args.json:
        write_json(args.json)


if __name__ == "__main__":
    main()
