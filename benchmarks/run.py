"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per the repo contract. Default is
a CI-sized budget; ``--full`` uses the budget behind EXPERIMENTS.md.

  T1  accuracy across alpha (non-IID severity) x methods     [Table 1]
  T2  heterogeneous client architectures                     [Table 2]
  T3  accuracy vs number of clients                          [Table 3]
  T4  DENSE + LDAM on skewed data                            [Table 4]
  T5  multi-round extension                                  [Table 5]
  T6  generator-loss ablation (CE / BN / div)                [Table 6]
  F3  one-shot FedAvg vs DENSE vs local models               [Figure 3]
  K   kernel microbenches (vs jnp oracle on CPU)             [kernels/]
  R   roofline summary from dry-run artifacts                [§Roofline]
"""
from __future__ import annotations

import argparse
import dataclasses
import glob
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (base_cfg, emit, ensemble_acc, get_federation,
                               run_method)


def t1_alpha_sweep(full: bool):
    alphas = (0.1, 0.3, 0.5) if full else (0.1, 0.5)
    methods = ("fedavg", "feddf", "feddafl", "fedadi", "dense")
    for alpha in alphas:
        scfg = dataclasses.replace(base_cfg(full), alpha=alpha)
        ens = ensemble_acc(scfg)
        emit(f"t1/ensemble_ceiling/alpha{alpha}", 0.0, f"acc={ens:.4f}")
        for m in methods:
            acc, dt = run_method(m, scfg)
            emit(f"t1/{m}/alpha{alpha}", dt, f"acc={acc:.4f}")


def t2_heterogeneous(full: bool):
    kinds = (("resnet18", "cnn1", "cnn2", "wrn16_1", "wrn40_1") if full
             else ("cnn1", "cnn2", "wrn16_1"))
    scfg = dataclasses.replace(
        base_cfg(full), client_kinds=kinds, n_clients=len(kinds),
        global_kind="wrn16_1" if not full else "resnet18")
    for m in ("feddf", "feddafl", "fedadi", "dense"):
        acc, dt = run_method(m, scfg)
        emit(f"t2/{m}/hetero{len(kinds)}", dt, f"acc={acc:.4f}")


def t3_num_clients(full: bool):
    ms = (5, 10, 20) if full else (3, 6)
    for n in ms:
        scfg = dataclasses.replace(base_cfg(full), n_clients=n,
                                   client_kinds=("cnn1",) * n)
        for m in (("fedavg", "feddf", "fedadi", "dense") if full
                  else ("fedavg", "dense")):
            acc, dt = run_method(m, scfg)
            emit(f"t3/{m}/m{n}", dt, f"acc={acc:.4f}")


def t4_ldam(full: bool):
    for alpha in ((0.1, 0.5) if full else (0.1,)):
        for ldam in (False, True):
            scfg = dataclasses.replace(base_cfg(full), alpha=alpha,
                                       use_ldam=ldam)
            acc, dt = run_method("dense", scfg)
            name = "dense+ldam" if ldam else "dense"
            emit(f"t4/{name}/alpha{alpha}", dt, f"acc={acc:.4f}")


def t5_multiround(full: bool):
    from repro.core import evaluate
    from repro.data import make_classification_data
    from repro.fl import dense_multi_round
    rounds = (1, 2, 3) if full else (1, 2)
    scfg = dataclasses.replace(base_cfg(full),
                               local_epochs=8 if full else 4)
    data = make_classification_data(0, num_classes=scfg.num_classes,
                                    size=scfg.image_size, ch=scfg.in_ch,
                                    train_per_class=scfg.train_per_class,
                                    test_per_class=scfg.test_per_class)
    xt, yt = data["test"]
    for tc in rounds:
        t0 = time.time()
        gp, spec, _ = dense_multi_round(jax.random.PRNGKey(0), scfg, data,
                                        rounds=tc)
        acc = evaluate(gp, spec, xt, yt)
        emit(f"t5/dense/rounds{tc}", time.time() - t0, f"acc={acc:.4f}")


def t6_ablation(full: bool):
    from repro.core import evaluate, train_dense_server
    scfg = base_cfg(full)
    data, clients, _ = get_federation(scfg)
    xt, yt = data["test"]
    variants = {"dense": {}, "w_ce_only": {"use_bn": False, "use_div": False},
                "wo_bn": {"use_bn": False}, "wo_div": {"use_div": False}}
    for name, kw in variants.items():
        t0 = time.time()
        stu, _, _ = train_dense_server(jax.random.PRNGKey(7), clients, scfg,
                                       **kw)
        acc = evaluate(stu, clients[0].spec, xt, yt)
        emit(f"t6/{name}", time.time() - t0, f"acc={acc:.4f}")


def f3_local_vs_global(full: bool):
    """Figure 3: DENSE above local models; one-shot FedAvg below them."""
    from repro.core import evaluate
    scfg = base_cfg(full)
    data, clients, _ = get_federation(scfg)
    xt, yt = data["test"]
    for i, c in enumerate(clients):
        acc = evaluate(c.params, c.spec, xt, yt)
        emit(f"f3/local{i}", 0.0, f"acc={acc:.4f}")
    for m in ("fedavg", "dense"):
        acc, dt = run_method(m, scfg)
        emit(f"f3/{m}", dt, f"acc={acc:.4f}")


def k_kernels(full: bool):
    from repro.kernels import ops, ref
    key = jax.random.PRNGKey(0)
    B, Hq, Hkv, S, D = 1, 4, 2, 256, 64
    q = jax.random.normal(key, (B, Hq, S, D))
    k = jax.random.normal(key, (B, Hkv, S, D))
    v = jax.random.normal(key, (B, Hkv, S, D))
    t0 = time.time()
    o = ops.flash_attention(q, k, v, block_q=64, block_k=64)
    jax.block_until_ready(o)
    err = float(jnp.max(jnp.abs(o - ref.attention(q, k, v))))
    emit("k/flash_attention/256x64", time.time() - t0,
         f"max_err={err:.2e};interpret=cpu")

    t_ = jax.random.normal(key, (64, 4096)) * 3
    s_ = jax.random.normal(jax.random.PRNGKey(1), (64, 4096)) * 3
    t0 = time.time()
    r = ops.distill_kl(t_, s_, 32, 1024)
    jax.block_until_ready(r)
    err = float(jnp.max(jnp.abs(r - ref.distill_kl(t_, s_))))
    emit("k/distill_kl/64x4096", time.time() - t0,
         f"max_err={err:.2e};interpret=cpu")

    x = jax.random.normal(key, (1, 256, 4, 32))
    dt = jax.nn.softplus(jax.random.normal(key, (1, 256, 4)))
    a = -jnp.exp(jax.random.normal(key, (4,)) * 0.3)
    b = jax.random.normal(key, (1, 256, 1, 32)) * 0.3
    c = jax.random.normal(key, (1, 256, 1, 32)) * 0.3
    t0 = time.time()
    y, st = ops.ssd_scan(x, dt, a, b, c, chunk=64)
    jax.block_until_ready(y)
    y2, _ = ref.ssd(x, dt, a, b, c)
    err = float(jnp.max(jnp.abs(y - y2)))
    emit("k/ssd_scan/256x4x32", time.time() - t0,
         f"max_err={err:.2e};interpret=cpu")


def r_roofline(full: bool):
    """Summarize dry-run artifacts (run repro.launch.dryrun first)."""
    files = sorted(glob.glob(os.path.join(
        os.path.dirname(__file__), "..", "artifacts", "dryrun", "*.json")))
    if not files:
        emit("r/roofline", 0.0,
             "no_artifacts;run=python -m repro.launch.dryrun --all")
        return
    for f in files:
        rec = json.load(open(f))
        tag = f"r/{rec['arch']}/{rec['shape']}/{rec['mesh']}"
        if rec.get("status") != "ok":
            emit(tag, 0.0, f"status={rec.get('status')}")
            continue
        t = rec.get("roofline") or rec["roofline_raw"]
        emit(tag, rec.get("compile_s", 0.0),
             (f"bottleneck={rec['bottleneck']};"
              f"compute_s={t['compute_s']:.4f};"
              f"memory_s={t['memory_s']:.4f};"
              f"collective_s={t['collective_s']:.6f};"
              f"useful_ratio={rec.get('useful_flops_ratio', 0.0):.3f}"))


TABLES = {"t1": t1_alpha_sweep, "t2": t2_heterogeneous, "t3": t3_num_clients,
          "t4": t4_ldam, "t5": t5_multiround, "t6": t6_ablation,
          "f3": f3_local_vs_global, "k": k_kernels, "r": r_roofline}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="EXPERIMENTS.md budget (slow)")
    ap.add_argument("--only", default=None,
                    help="comma list of tables, e.g. t1,t6,k")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(TABLES)
    print("name,us_per_call,derived", flush=True)
    for n in names:
        TABLES[n](args.full)


if __name__ == "__main__":
    main()
