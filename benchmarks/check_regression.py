"""Bench-trajectory regression gate.

Compares a fresh ``benchmarks/run.py --json`` document against the
committed baseline (the previous PR's trajectory artifact, e.g.
BENCH_PR3.json) on **per-series medians** — the only stats in the file
that pool directly-comparable records (see common.write_json) — and
exits nonzero when any previously-measured series slowed down by more
than ``--threshold`` (default 1.5x).

Noise tolerance, deliberately asymmetric (only *slowdowns* can fail):

  * series whose baseline median is below ``--min-us`` are reported but
    never fail — sub-50µs timings on a shared CI host are dispatch
    jitter, and a 1.5x ratio of jitter is meaningless;
  * series present on only one side are reported but never fail —
    tables get added (this PR adds ``kl``) and renamed; the gate only
    guards series both documents measured;
  * when the two documents record different measurement environments
    (python version / backend / device count — e.g. a dev-box baseline
    vs the CI runner), absolute medians are not comparable across them:
    the gate downgrades to REPORT-ONLY (prints every ratio, exits 0).
    The ARMED instance in CI therefore compares against a baseline the
    runner itself produced — .github/workflows/ci.yml caches the fresh
    JSON of every main push (actions/cache) and gates PRs against that
    same-environment copy; the committed BENCH_PR*.json comparison runs
    alongside as the cross-PR trajectory record;
  * ``SKIP_BENCH_GATE=1`` (or the ``skip-bench-gate`` PR label, wired as
    a step condition in .github/workflows/ci.yml) skips the gate for
    known-noisy or intentionally-slower changes.

Usage:
  python benchmarks/check_regression.py BASELINE.json FRESH.json \
      [--threshold 1.5] [--min-us 50]
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def series_medians(doc: dict) -> dict[str, float]:
    return {name: rec["median_us"]
            for name, rec in doc.get("series", {}).items()}


def env_key(doc: dict) -> tuple:
    """The fields that must match for absolute medians to be comparable.

    Python is compared at major.minor only: a runner-image patch bump
    (3.11.9 -> 3.11.10) does not change machine speed, and keying on it
    would silently disarm the CI gate until the next baseline refresh."""
    py = str(doc.get("python") or "")
    return (".".join(py.split(".")[:2]), doc.get("backend"),
            doc.get("device_count"))


def compare(base: dict[str, float], fresh: dict[str, float], *,
            threshold: float, min_us: float):
    """-> (rows, offenders): every shared series with its ratio, and the
    subset that fails the gate."""
    rows, offenders = [], []
    for name in sorted(set(base) | set(fresh)):
        b, f = base.get(name), fresh.get(name)
        if b is None or f is None:
            rows.append((name, b, f, None, "only-" +
                         ("fresh" if b is None else "baseline")))
            continue
        if b <= min_us or f <= 0.0:
            rows.append((name, b, f, None, "sub-noise-floor"))
            continue
        ratio = f / b
        verdict = "REGRESSION" if ratio > threshold else "ok"
        rows.append((name, b, f, ratio, verdict))
        if ratio > threshold:
            offenders.append((name, b, f, ratio))
    return rows, offenders


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fail on >threshold per-series bench slowdowns")
    ap.add_argument("baseline", help="committed trajectory JSON "
                                     "(previous PR's artifact)")
    ap.add_argument("fresh", help="freshly generated trajectory JSON")
    ap.add_argument("--threshold", type=float, default=1.5,
                    help="max allowed fresh/baseline median ratio")
    ap.add_argument("--min-us", type=float, default=50.0,
                    help="baseline medians below this are jitter, "
                         "never gated")
    args = ap.parse_args(argv)

    if os.environ.get("SKIP_BENCH_GATE") == "1":
        print("check_regression: SKIP_BENCH_GATE=1 — gate skipped")
        return 0

    with open(args.baseline) as fh:
        base_doc = json.load(fh)
    with open(args.fresh) as fh:
        fresh_doc = json.load(fh)
    rows, offenders = compare(series_medians(base_doc),
                              series_medians(fresh_doc),
                              threshold=args.threshold, min_us=args.min_us)

    print(f"# baseline={args.baseline} ({base_doc.get('backend')}, "
          f"jax {base_doc.get('jax')}) vs fresh={args.fresh} "
          f"({fresh_doc.get('backend')}, jax {fresh_doc.get('jax')})")
    print("series,baseline_us,fresh_us,ratio,verdict")
    for name, b, f, ratio, verdict in rows:
        print(f"{name},{'' if b is None else round(b, 1)},"
              f"{'' if f is None else round(f, 1)},"
              f"{'' if ratio is None else round(ratio, 3)},{verdict}")

    if offenders:
        if env_key(base_doc) != env_key(fresh_doc):
            print(f"\ncheck_regression: REPORT-ONLY — {len(offenders)} "
                  f"series exceed {args.threshold}x but the baseline was "
                  f"measured on a different environment "
                  f"({env_key(base_doc)} vs {env_key(fresh_doc)}); commit "
                  f"a baseline from this environment to arm the gate")
            return 0
        print(f"\ncheck_regression: FAILED — {len(offenders)} series "
              f"slower than {args.threshold}x:", file=sys.stderr)
        for name, b, f, ratio in offenders:
            print(f"  {name}: {b:.1f}us -> {f:.1f}us ({ratio:.2f}x)",
                  file=sys.stderr)
        print("(re-run locally with scripts/tier1.sh, or apply the "
              "`skip-bench-gate` label / SKIP_BENCH_GATE=1 for known-noisy "
              "changes)", file=sys.stderr)
        return 1
    print("\ncheck_regression: ok")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
