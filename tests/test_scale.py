"""Federation-scale invariants (DESIGN.md §13): bucketed batch plans,
chunked group setup, hierarchical fedavg, chunked ensemble teacher.

The m=1000 scaling layers are all pure execution-shape knobs — every
test here pins an equivalence: bucketing/chunking never change a
client's trained params (bitwise), the tree reduce matches the flat
weighted sum to fp32 tolerance, the chunked teacher matches the
one-shot stacked forward, and survivor masks compose with buckets
unchanged. Plus the one inequality the knobs exist for: padded-step
waste under Dirichlet-like skew drops >= 3x with bucketing on.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.backend import resolve_exec_policy
from repro.configs.paper_cifar import DenseExperimentConfig
from repro.core.ensemble import (ensemble_logits, grouped_ensemble_logits,
                                 stack_grouped)
from repro.data.pipeline import (batches, bucket_members, build_batch_plan,
                                 plan_step_waste)
from repro.fl import admit_uploads, fedavg_stacked, train_clients_grouped
from repro.fl.client import local_update_bucketed
from repro.models.cnn import CNNSpec, cnn_init

SPEC = CNNSpec(kind="cnn1", num_classes=4, in_ch=1, width=0.25,
               image_size=8)

# long-tailed shard sizes, the shape Dirichlet alpha<=0.1 produces:
# a few heavy clients, a long tail of tiny ones
SKEWED = [530, 410, 61, 55, 48, 40, 33, 29, 21, 17, 13, 11, 9, 7, 5, 3]


def _shards(sizes, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for n in sizes:
        x = rng.standard_normal((n, 8, 8, 1)).astype(np.float32)
        y = rng.integers(0, 4, n)
        out.append((x, y))
    return out


def _assert_bitwise(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ------------------------------------------------------------- bucketing ---

@pytest.mark.parametrize("mode", ["off", "pow2", "quantile"])
def test_bucket_members_is_ordered_partition(mode):
    sizes = SKEWED
    buckets = bucket_members(sizes, 16, mode)
    flat = [i for b in buckets for i in b]
    assert sorted(flat) == list(range(len(sizes)))
    for b in buckets:                      # original order within a bucket
        assert list(b) == sorted(b)
    nb = [-(-n // 16) for n in sizes]
    bmax = [max(nb[i] for i in b) for b in buckets]
    assert bmax == sorted(bmax)            # ascending compile shapes


def test_bucketing_never_changes_minibatch_streams():
    """A client's seeded (idx, mask) stream restricted to valid slots is
    identical whether its plan was padded to the group max (unbucketed)
    or its bucket max (steps_per_epoch override)."""
    sizes, batch, epochs = [37, 21, 130, 5], 16, 2
    seeds = [11, 12, 13, 14]
    for members in bucket_members(sizes, batch, "pow2"):
        nb_bucket = max(-(-sizes[j] // batch) for j in members)
        plan = build_batch_plan([sizes[j] for j in members], batch,
                                epochs=epochs,
                                seeds=[seeds[j] for j in members],
                                steps_per_epoch=nb_bucket)
        for k, j in enumerate(members):
            n = sizes[j]
            x = np.arange(n)[:, None]
            want = [bx[:, 0] for bx, _ in
                    batches(x, np.zeros(n, np.int64), batch,
                            seed=seeds[j], epochs=epochs)]
            got = [plan.idx[k, s][plan.mask[k, s]]
                   for s in range(plan.steps) if plan.mask[k, s].any()]
            assert len(want) == len(got)
            for w, g in zip(want, got):
                np.testing.assert_array_equal(w, g)


def test_bucketing_cuts_step_waste_3x_under_dirichlet_skew():
    """The acceptance bound: on a real Dirichlet alpha=0.1 partition both
    bucketing modes cut fully-masked padding steps >= 3x vs one plan (and
    pow2 holds the bound at m=100, where the long tail is longest)."""
    from repro.data.partition import dirichlet_partition
    y = np.random.default_rng(0).integers(0, 10, 20000)
    sizes16 = [max(1, len(p)) for p in dirichlet_partition(y, 16, 0.1,
                                                           seed=0)]
    base = plan_step_waste(sizes16, 16, "off")
    assert base > 0.3                      # single plan is mostly padding
    for mode in ("pow2", "quantile"):
        w = plan_step_waste(sizes16, 16, mode)
        assert w <= base / 3.0, (mode, w, base)
    sizes100 = [max(1, len(p)) for p in dirichlet_partition(y, 100, 0.1,
                                                            seed=0)]
    base100 = plan_step_waste(sizes100, 16, "off")
    assert plan_step_waste(sizes100, 16, "pow2") <= base100 / 3.0


def test_plan_step_waste_off_is_exact():
    # nb = [3, 2, 1], padded to 3 each: 9 scheduled, 6 real
    assert plan_step_waste([33, 17, 2], 16, "off") == pytest.approx(1 / 3)


def test_dirichlet_partition_terminates_at_m1000():
    """The partitioner's min-size rejection loop is infeasible at
    m=1000/alpha=0.1 (the all-clients-fed event ~never happens); the
    bounded-retry + deterministic repair must terminate, respect the
    floor, and still produce an exact index partition."""
    from repro.data.partition import dirichlet_partition
    y = np.random.default_rng(0).integers(0, 4, 8000)
    parts = dirichlet_partition(y, 1000, 0.1, seed=0)
    sizes = [len(p) for p in parts]
    assert min(sizes) >= 2 and sum(sizes) == 8000
    assert len(set(np.concatenate(parts).tolist())) == 8000
    with pytest.raises(ValueError):
        dirichlet_partition(y[:100], 1000, 0.1)


# --------------------------------------- bucketed/chunked local update ----

def test_bucketed_chunked_local_update_is_bitwise():
    """bucketing + chunking are execution-shape knobs only: trained
    params come back BITWISE identical to the single-plan path, in
    original member order."""
    sizes = [37, 21, 130, 5, 64, 12]
    shards = _shards(sizes, seed=3)
    seeds = list(range(20, 26))
    inits = [cnn_init(jax.random.PRNGKey(i), SPEC) for i in range(6)]
    counts = np.stack([np.bincount(y, minlength=4) for _, y in shards])

    def run(bucketing, chunk):
        return local_update_bucketed(
            lambda j: inits[j], SPEC, shards, batch_size=16, epochs=2,
            seeds=seeds, use_ldam=False, num_classes=4,
            class_counts=counts, bucketing=bucketing, chunk=chunk)

    ref = run("off", None)
    for bucketing, chunk in (("off", 2), ("pow2", None), ("pow2", 2),
                             ("quantile", 3)):
        _assert_bitwise(run(bucketing, chunk), ref)


# ------------------------------------------------------- chunked stacking --

def test_stack_grouped_chunked_is_bitwise():
    clients = [dataclasses.replace(
        _client(i), n_data=10) for i in range(5)]
    _, full = stack_grouped(clients)
    _, chunked = stack_grouped(clients, chunk=2)
    _assert_bitwise(full, chunked)


def _client(i, spec=SPEC, n_data=10):
    from repro.core.ensemble import Client
    return Client(spec=spec, params=cnn_init(jax.random.PRNGKey(i), spec),
                  n_data=n_data)


# -------------------------------------------------------- chunked teacher --

@pytest.mark.parametrize("with_stats", [False, True])
def test_chunked_teacher_matches_unchunked(with_stats):
    clients = [_client(i) for i in range(5)]
    gspecs, gparams = stack_grouped(clients)
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal((6, 8, 8, 1)).astype(np.float32))
    ref = grouped_ensemble_logits(gspecs, gparams, x,
                                  with_bn_stats=with_stats)
    for chunk in (1, 2, 3, 5, 16):
        got = grouped_ensemble_logits(gspecs, gparams, x,
                                      with_bn_stats=with_stats,
                                      chunk=chunk)
        if with_stats:
            lg, st = got
            lr, sr = ref
            np.testing.assert_allclose(np.asarray(lg), np.asarray(lr),
                                       atol=1e-5)
            for sa, sb in zip(st, sr):
                for da, db in zip(sa, sb):
                    for f in da:
                        np.testing.assert_allclose(
                            np.asarray(da[f]), np.asarray(db[f]),
                            atol=1e-5)
        else:
            np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                       atol=1e-5)


def test_chunked_teacher_matches_listwise_reference():
    clients = [_client(i) for i in range(4)]
    gspecs, gparams = stack_grouped(clients)
    rng = np.random.default_rng(8)
    x = jnp.asarray(rng.standard_normal((3, 8, 8, 1)).astype(np.float32))
    want = ensemble_logits([c.spec for c in clients],
                           [c.params for c in clients], x)
    got = grouped_ensemble_logits(gspecs, gparams, x, chunk=2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5)


def test_chunked_teacher_grads_match():
    """Differentiating through the scanned/checkpointed chunk loop gives
    the same generator-side gradients as the one-shot stacked forward."""
    clients = [_client(i) for i in range(5)]
    gspecs, gparams = stack_grouped(clients)
    rng = np.random.default_rng(9)
    x0 = jnp.asarray(rng.standard_normal((4, 8, 8, 1)).astype(np.float32))

    def loss(x, chunk):
        lg = grouped_ensemble_logits(gspecs, gparams, x, chunk=chunk)
        return jnp.sum(jax.nn.log_softmax(lg) ** 2)

    g_ref = jax.grad(loss)(x0, None)
    g_chk = jax.grad(loss)(x0, 2)
    np.testing.assert_allclose(np.asarray(g_chk), np.asarray(g_ref),
                               atol=1e-5)


# ------------------------------------------------------------ tree fedavg --

def test_tree_fedavg_matches_flat():
    rng = np.random.default_rng(10)
    m = 13
    stacked = {"w": jnp.asarray(rng.standard_normal((m, 5, 3)),
                                jnp.float32),
               "b": jnp.asarray(rng.standard_normal((m, 3)), jnp.float32)}
    n_data = rng.integers(1, 500, m).tolist()
    flat = fedavg_stacked(stacked, n_data)
    for branch in (2, 3, 8, 16):
        tree = fedavg_stacked(stacked, n_data, mode="tree", branch=branch)
        for a, b in zip(jax.tree.leaves(flat), jax.tree.leaves(tree)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-6)


def test_tree_fedavg_respects_survivor_mask():
    rng = np.random.default_rng(11)
    m = 9
    stacked = {"w": jnp.asarray(rng.standard_normal((m, 4)), jnp.float32)}
    n_data = rng.integers(1, 100, m).tolist()
    mask = np.array([True, False, True, True, True, False, True, True,
                     True])
    flat = fedavg_stacked(stacked, n_data, survivor_mask=mask)
    tree = fedavg_stacked(stacked, n_data, survivor_mask=mask,
                          mode="tree", branch=4)
    np.testing.assert_allclose(np.asarray(tree["w"]),
                               np.asarray(flat["w"]), atol=1e-6)


def test_fedavg_unknown_mode_raises():
    stacked = {"w": jnp.ones((2, 3))}
    with pytest.raises(ValueError):
        fedavg_stacked(stacked, [1, 1], mode="nope")


# ----------------------------------- survivor masks compose with buckets ---

def test_quarantine_composes_with_bucketed_training():
    """admit_uploads survivor masks act on the ORIGINAL member order the
    bucketed engine restores, so masked fedavg over a bucketed+chunked
    federation == masked fedavg over the single-plan federation,
    bitwise."""
    m = 6
    sizes = [37, 21, 130, 5, 64, 12]
    shards = _shards(sizes, seed=13)
    specs = [SPEC] * m
    keys = list(jax.random.split(jax.random.PRNGKey(0), m))
    seeds = list(range(m))
    kw = dict(epochs=1, lr=0.05, momentum=0.9, batch_size=16,
              use_ldam=False, num_classes=4, seeds=seeds, init_keys=keys)
    pol = resolve_exec_policy(DenseExperimentConfig(
        plan_bucketing="pow2", stack_chunk=2))
    ref = train_clients_grouped(specs, shards, **kw)
    buck = train_clients_grouped(specs, shards, **kw, policy=pol)
    _assert_bitwise(ref.grouped[1], buck.grouped[1])

    arrived = np.array([True, True, False, True, True, True])
    aref = admit_uploads(ref, arrived=arrived)
    abuck = admit_uploads(buck, arrived=arrived)
    np.testing.assert_array_equal(aref.survivor_mask, abuck.survivor_mask)
    fa = fedavg_stacked(aref.grouped[1][0], [c.n_data for c in aref],
                        survivor_mask=aref.survivor_mask)
    fb = fedavg_stacked(abuck.grouped[1][0], [c.n_data for c in abuck],
                        survivor_mask=abuck.survivor_mask, mode="tree",
                        branch=2)
    for a, b in zip(jax.tree.leaves(fa), jax.tree.leaves(fb)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6)


# ------------------------------------------------------------ m=100 smoke --

@pytest.mark.slow
def test_m100_federation_smoke():
    """A m=100 skewed federation runs the whole scaled local phase —
    quantile buckets, chunk-16 group setup, tree fedavg, chunked
    teacher — and stays equivalent to the flat reductions. This is the
    CI forced-8-device scale smoke (ci.yml sets
    xla_force_host_platform_device_count=8)."""
    m = 100
    rng = np.random.default_rng(42)
    sizes = np.maximum(3, (rng.pareto(1.5, m) * 20).astype(int)).tolist()
    shards = _shards(sizes, seed=17)
    specs = [SPEC] * m
    keys = list(jax.random.split(jax.random.PRNGKey(1), m))
    pol = resolve_exec_policy(DenseExperimentConfig(
        plan_bucketing="quantile", stack_chunk=16, fedavg_mode="tree",
        fedavg_branch=8, teacher_chunk=16))
    clients = train_clients_grouped(
        specs, shards, epochs=1, lr=0.05, momentum=0.9, batch_size=16,
        use_ldam=False, num_classes=4, seeds=list(range(m)),
        init_keys=keys, policy=pol)
    gspecs, gparams = clients.grouped
    assert gspecs == ((SPEC, m),)
    assert all(np.isfinite(np.asarray(a)).all()
               for a in jax.tree.leaves(gparams))

    n_data = [c.n_data for c in clients]
    flat = fedavg_stacked(gparams[0], n_data)
    tree = fedavg_stacked(gparams[0], n_data, mode="tree",
                          branch=pol.fedavg_branch)
    for a, b in zip(jax.tree.leaves(flat), jax.tree.leaves(tree)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5)

    x = jnp.asarray(np.random.default_rng(5).standard_normal(
        (4, 8, 8, 1)).astype(np.float32))
    full = grouped_ensemble_logits(gspecs, gparams, x)
    chunked = grouped_ensemble_logits(gspecs, gparams, x,
                                      chunk=pol.teacher_chunk)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(full),
                               atol=1e-4)
