"""Sharding-rule validity for every FULL config x both production meshes —
the structural core of the dry-run: every PartitionSpec axis must evenly
divide the corresponding dim. Uses abstract shapes only (no devices)."""
from types import SimpleNamespace

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import available_archs, get_config
from repro.launch import shardings as SH
from repro.launch import specs as SP

MESH1 = SimpleNamespace(axis_names=("data", "model"),
                        shape={"data": 16, "model": 16})
MESH2 = SimpleNamespace(axis_names=("pod", "data", "model"),
                        shape={"pod": 2, "data": 16, "model": 16})


def _check_divisibility(tree, specs, mesh, where=""):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    sleaves = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    assert len(leaves) == len(sleaves)
    for (path, leaf), spec in zip(leaves, sleaves):
        assert len(spec) <= len(leaf.shape), (where, path, spec, leaf.shape)
        for dim, ax in enumerate(spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = int(np.prod([mesh.shape[a] for a in axes]))
            assert leaf.shape[dim] % size == 0, \
                (where, jax.tree_util.keystr(path), dim, leaf.shape, spec)


@pytest.mark.parametrize("arch", available_archs())
@pytest.mark.parametrize("mesh", [MESH1, MESH2], ids=["16x16", "2x16x16"])
def test_param_specs_divide(arch, mesh):
    cfg = get_config(arch)
    aparams = SP.abstract_params(cfg)
    specs = SH.param_specs(cfg, aparams, mesh)
    _check_divisibility(aparams, specs, mesh, where=arch)


@pytest.mark.parametrize("arch", available_archs())
def test_zero1_specs_divide_and_shard_big_leaves(arch):
    cfg = get_config(arch)
    aparams = SP.abstract_params(cfg)
    specs = SH.param_specs(cfg, aparams, MESH1)
    z = SH.zero1_specs(specs, aparams, MESH1)
    _check_divisibility(aparams, z, MESH1, where=arch)
    # at least one big replicated leaf gained a 'data' axis
    got_data = any("data" in [a for a in spec if a]
                   for spec in jax.tree_util.tree_leaves(
                       z, is_leaf=lambda x: isinstance(x, P)))
    assert got_data, arch


@pytest.mark.parametrize("arch", available_archs())
@pytest.mark.parametrize("shape", ["decode_32k", "long_500k"])
def test_cache_specs_divide(arch, shape):
    cfg = get_config(arch)
    if shape == "long_500k" and not SP.long_context_ok(cfg):
        pytest.skip("full-attention arch skips long_500k (DESIGN.md §5)")
    spec = SP.input_specs(cfg, shape)
    cspecs = SH.cache_specs(cfg, spec["cache"], MESH1,
                            batch=SP.SHAPES[shape]["batch"])
    _check_divisibility(spec["cache"], cspecs, MESH1, where=f"{arch}/{shape}")


def test_batch_specs():
    assert SH.batch_specs(MESH1, 256) == ("data",)
    assert SH.batch_specs(MESH2, 256) == ("pod", "data")
    assert SH.batch_specs(MESH1, 1) is None
    assert SH.batch_specs(MESH2, 2) is None  # 2 % 32 != 0


def test_long_context_policy():
    ok = [a for a in available_archs()
          if SP.long_context_ok(get_config(a))]
    assert sorted(ok) == ["gemma3-4b", "mamba2-130m", "zamba2-7b"]


def test_attn_sharding_flags():
    assert SH.attn_sharded(get_config("musicgen-large"), MESH1)
    assert SH.attn_sharded(get_config("deepseek-v2-236b"), MESH1)
    assert SH.attn_sharded(get_config("zamba2-7b"), MESH1)
    assert not SH.attn_sharded(get_config("gemma3-4b"), MESH1)   # 8q/4kv
    assert not SH.attn_sharded(get_config("phi3-medium-14b"), MESH1)  # 40/10
    assert SH.ssm_sharded(get_config("zamba2-7b"), MESH1)        # 112 heads
    assert not SH.ssm_sharded(get_config("mamba2-130m"), MESH1)  # 24 heads
