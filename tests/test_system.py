"""End-to-end behaviour tests for DENSE (the paper's system).

Micro-scale (8x8 images, width-0.25 CNNs, handfuls of epochs): asserts the
*mechanics* — one-shot protocol, two-stage training, heterogeneous
support, multi-round extension — not accuracies (benchmarks/ cover the
paper's relative claims at a larger budget)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_cifar import DenseExperimentConfig
from repro.core import (Client, evaluate, train_dense_server)
from repro.core.dense import merge_bn_stats
from repro.data import make_classification_data
from repro.fl import (CommLedger, build_federation, fed_adi, fed_dafl,
                      fed_df, fedavg, param_bytes)

SCFG = DenseExperimentConfig(
    n_clients=2, alpha=0.5, local_epochs=2, batch_size=32,
    num_classes=4, image_size=8, in_ch=1, train_per_class=24,
    test_per_class=8, client_kinds=("cnn1", "cnn1"), global_kind="cnn1",
    width=0.25, nz=16, t_g=2, epochs=3, synth_batch=32, s_steps=2)


@pytest.fixture(scope="module")
def federation():
    data = make_classification_data(0, num_classes=SCFG.num_classes,
                                    size=SCFG.image_size, ch=SCFG.in_ch,
                                    train_per_class=SCFG.train_per_class,
                                    test_per_class=SCFG.test_per_class)
    ledger = CommLedger()
    clients, shards = build_federation(jax.random.PRNGKey(0), SCFG, data,
                                       ledger=ledger)
    return data, clients, ledger


def test_one_shot_communication_profile(federation):
    _, clients, ledger = federation
    assert ledger.rounds == 1                      # ONE round
    assert ledger.downlink_bytes == 0              # nothing broadcast
    assert ledger.uplink_bytes == sum(param_bytes(c.params)
                                      for c in clients)


def test_dense_two_stage_runs_and_learns_structure(federation):
    data, clients, _ = federation
    stu, gen_p, hist = train_dense_server(jax.random.PRNGKey(1), clients,
                                          SCFG)
    assert len(hist.gen_loss) == SCFG.epochs
    assert all(np.isfinite(v) for v in hist.gen_loss)
    assert all(np.isfinite(v) for v in hist.dis_loss)
    # all three generator loss parts present and finite (Eq. 5)
    assert set(hist.gen_parts[0]) == {"ce", "bn", "div"}
    xt, yt = data["test"]
    acc = evaluate(stu, clients[0].spec, xt, yt)
    assert 0.0 <= acc <= 1.0


def test_dense_ablations_run(federation):
    """w/o L_BN and w/o L_div paths (paper Table 6)."""
    _, clients, _ = federation
    for kw in ({"use_bn": False}, {"use_div": False},
               {"use_bn": False, "use_div": False}):
        _, _, hist = train_dense_server(jax.random.PRNGKey(2), clients,
                                        SCFG, **kw)
        if not kw.get("use_bn", True):
            assert all(p["bn"] == 0.0 for p in hist.gen_parts)
        if not kw.get("use_div", True):
            assert all(p["div"] == 0.0 for p in hist.gen_parts)


def test_heterogeneous_federation_end_to_end():
    """Different client architectures; FedAvg impossible, DENSE fine
    (paper Table 2)."""
    scfg = dataclasses.replace(SCFG, client_kinds=("cnn1", "cnn2"),
                               global_kind="wrn16_1")
    data = make_classification_data(3, num_classes=scfg.num_classes,
                                    size=scfg.image_size, ch=scfg.in_ch,
                                    train_per_class=scfg.train_per_class,
                                    test_per_class=scfg.test_per_class)
    clients, _ = build_federation(jax.random.PRNGKey(3), scfg, data)
    with pytest.raises(ValueError):
        fedavg(clients)
    stu, _, hist = train_dense_server(jax.random.PRNGKey(4), clients, scfg)
    assert np.isfinite(hist.dis_loss[-1])


def test_baselines_run(federation):
    data, clients, _ = federation
    xt, yt = data["test"]
    for fn in (fed_df, fed_dafl, fed_adi):
        stu, spec = fn(jax.random.PRNGKey(5), clients, SCFG)
        acc = evaluate(stu, spec, xt, yt)
        assert 0.0 <= acc <= 1.0


def test_multi_round_extension():
    from repro.fl import dense_multi_round
    scfg = dataclasses.replace(SCFG, local_epochs=1, epochs=2)
    data = make_classification_data(5, num_classes=scfg.num_classes,
                                    size=scfg.image_size, ch=scfg.in_ch,
                                    train_per_class=scfg.train_per_class,
                                    test_per_class=scfg.test_per_class)
    led = CommLedger()
    gp, spec, _ = dense_multi_round(jax.random.PRNGKey(6), scfg, data,
                                    rounds=2, ledger=led)
    assert led.rounds == 2
    assert led.downlink_bytes > 0   # broadcasts happen between rounds
    assert gp is not None


def test_merge_bn_stats_only_touches_running_stats():
    a = {"bn": {"scale": jnp.ones(2), "mean": jnp.zeros(2),
                "var": jnp.ones(2)},
         "w": jnp.zeros(3)}
    b = {"bn": {"scale": jnp.full(2, 9.0), "mean": jnp.full(2, 5.0),
                "var": jnp.full(2, 7.0)},
         "w": jnp.full(3, 9.0)}
    out = merge_bn_stats(a, b)
    np.testing.assert_array_equal(np.asarray(out["bn"]["mean"]),
                                  np.full(2, 5.0))
    np.testing.assert_array_equal(np.asarray(out["bn"]["var"]),
                                  np.full(2, 7.0))
    np.testing.assert_array_equal(np.asarray(out["bn"]["scale"]),
                                  np.ones(2))
    np.testing.assert_array_equal(np.asarray(out["w"]), np.zeros(3))


def test_dense_llm_smoke():
    """The LLM-scale DENSE instantiation (core/dense_llm.py) with two
    heterogeneous reduced LM clients sharing a vocab."""
    from repro.configs.base import get_smoke_config
    from repro.core import dense_llm as DL
    from repro.core.generator import tok_generator_init
    from repro.models import transformer as T

    c1 = get_smoke_config("llama3.2-3b")
    c2 = get_smoke_config("qwen1.5-4b").replace(vocab_size=c1.vocab_size)
    stu_cfg = get_smoke_config("phi3-medium-14b").replace(
        vocab_size=c1.vocab_size)
    key = jax.random.PRNGKey(0)
    cp = [T.init_model(jax.random.PRNGKey(1), c1),
          T.init_model(jax.random.PRNGKey(2), c2)]
    stu_p = T.init_model(jax.random.PRNGKey(3), stu_cfg)
    gen_p = tok_generator_init(key, nz=8, seq=16, d_model=stu_cfg.d_model,
                               d_g=32, n_classes=stu_cfg.vocab_size)
    gstep, sstep, g_opt, s_opt = DL.make_llm_dense_steps(
        stu_cfg, [c1, c2], gen_seq=16, nz=8)
    gs, ss = g_opt.init(gen_p), s_opt.init(stu_p)
    z = jax.random.normal(key, (2, 8))
    y = jax.random.randint(key, (2, 16), 0, stu_cfg.vocab_size)
    gen_p, gs, gl, parts = gstep(gen_p, gs, stu_p, cp, z, y)
    assert np.isfinite(float(gl))
    assert all(np.isfinite(float(v)) for v in parts.values())
    losses = []
    for i in range(3):
        stu_p, ss, dl = sstep(stu_p, ss, gen_p, cp, z, y)
        losses.append(float(dl))
    assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0]    # distillation reduces teacher-student KL
