"""Unit tests for dry-run instrumentation: the HLO collective parser and
analytic FLOPs model (no device work — pure text/number manipulation)."""
import numpy as np

from repro.configs.base import get_config
from repro.launch.dryrun import collective_bytes, model_flops, depth_pair
from repro.launch import specs as SP

HLO_SAMPLE = """
ENTRY %main {
  %ag = bf16[16,512,2560]{2,1,0} all-gather(%x), replica_groups=...
  %ar.1 = f32[4096]{0} all-reduce(%y), to_apply=%add
  %ars = f32[8,16]{1,0} all-reduce-start(%z), to_apply=%add
  %rs = bf16[2,64]{1,0} reduce-scatter(%w), dimensions={0}
  %a2a = s32[128]{0} all-to-all(%v), dimensions={0}
  %cp = bf16[4,4]{1,0} collective-permute(%u), source_target_pairs=...
  %dot = f32[128,128]{1,0} dot(%a, %b)
}
"""


def test_collective_parser_counts_each_kind():
    c = collective_bytes(HLO_SAMPLE)
    assert c["all-gather"] == 16 * 512 * 2560 * 2
    assert c["all-reduce"] == 4096 * 4 + 8 * 16 * 4   # incl. -start form
    assert c["reduce-scatter"] == 2 * 64 * 2
    assert c["all-to-all"] == 128 * 4
    assert c["collective-permute"] == 4 * 4 * 2
    assert c["count"] == 6
    assert c["total"] == sum(c[k] for k in
                             ("all-reduce", "all-gather", "reduce-scatter",
                              "all-to-all", "collective-permute"))


def test_collective_parser_empty():
    assert collective_bytes("ENTRY %m { %d = f32[2]{0} add(%a, %b) }")[
        "total"] == 0


def test_model_flops_train_vs_decode():
    cfg = get_config("llama3.2-3b")
    n = cfg.param_count()
    t = model_flops(cfg, "train_4k")
    np.testing.assert_allclose(t, 6 * n * 256 * 4096, rtol=1e-6)
    d = model_flops(cfg, "decode_32k")
    np.testing.assert_allclose(d, 2 * n * 128, rtol=1e-6)   # one token/seq


def test_model_flops_moe_uses_active_params():
    cfg = get_config("deepseek-v2-236b")
    assert model_flops(cfg, "train_4k") \
        == 6.0 * cfg.active_param_count() * 256 * 4096
    assert cfg.active_param_count() < 0.2 * cfg.param_count()


def test_depth_pair_by_family():
    assert depth_pair(get_config("llama3.2-3b")) == (2, 4)
    assert depth_pair(get_config("zamba2-7b")) == (6, 12)      # superblock
    assert depth_pair(get_config("llama-3.2-vision-11b")) == (5, 10)


def test_input_specs_shapes():
    cfg = get_config("phi3-medium-14b")
    s = SP.input_specs(cfg, "train_4k")
    assert s["batch_inputs"]["tokens"].shape == (256, 4096)
    d = SP.input_specs(cfg, "decode_32k")
    assert d["tokens"].shape == (128, 1)
    kv = d["cache"]["layers"]["k"]
    assert kv.shape == (40, 128, 32768, 10, 128)
    v = SP.input_specs(get_config("llama-3.2-vision-11b"), "prefill_32k")
    assert v["vision"].shape == (32, 1601, 4096)
