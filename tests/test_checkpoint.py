"""checkpoint/io.py round-trips + DENSE server-loop resume.

The checkpoint layer is what makes a killed DENSE run recoverable
(scfg.checkpoint_every / checkpoint_path, DESIGN.md §10): the full server
state — generator/student params, both optimizer states, the base
epoch-key and the epoch index — round-trips through one npz file, and a
resumed run replays the remaining epochs bit-identically because both
drivers re-derive the per-epoch key stream from the restored base key.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (checkpoint_exists, load_meta,
                              restore_checkpoint, save_checkpoint)
from repro.configs.paper_cifar import DenseExperimentConfig
from repro.core.dense import train_dense_server
from repro.data import make_classification_data
from repro.fl import build_federation

SCFG = DenseExperimentConfig(
    n_clients=3, alpha=0.5, local_epochs=2, batch_size=16, num_classes=4,
    image_size=8, in_ch=1, train_per_class=37, test_per_class=8,
    client_kinds=("cnn1",) * 3, global_kind="cnn1", width=0.25, nz=16,
    t_g=1, epochs=6, synth_batch=16)


def _leaves_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ------------------------------------------------------------ round trip ---

def test_roundtrip_nested_pytree_and_dtypes(tmp_path):
    """Nested dict + list pytree round-trips with leaf dtypes preserved
    (f32 params, f16 halves, int32 counters, uint32 PRNG keys)."""
    tree = {"params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                       "b": jnp.ones((3,), jnp.float16)},
            "opt": [{"m": jnp.zeros((2, 3), jnp.float32),
                     "t": jnp.asarray(7, jnp.int32)},
                    jnp.asarray([1, 2], jnp.int64)],
            "key": jax.random.PRNGKey(3)}
    path = os.path.join(tmp_path, "ck")
    save_checkpoint(path, tree)
    assert checkpoint_exists(path) and checkpoint_exists(path + ".npz")
    back = restore_checkpoint(path, jax.tree.map(np.zeros_like, tree))
    assert jax.tree_util.tree_structure(back) == \
        jax.tree_util.tree_structure(tree)
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(tree)):
        assert np.asarray(a).dtype == np.asarray(b).dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_casts_to_like_dtypes(tmp_path):
    """Leaves come back in the `like` tree's dtypes even when the stored
    dtype differs (e.g. a checkpoint written from an f32 run restored
    into an f16 template)."""
    path = os.path.join(tmp_path, "ck")
    save_checkpoint(path, {"w": np.ones((2,), np.float64)})
    back = restore_checkpoint(path, {"w": jnp.zeros((2,), jnp.float16)})
    assert np.asarray(back["w"]).dtype == np.float16


def test_meta_json(tmp_path):
    path = os.path.join(tmp_path, "ck")
    save_checkpoint(path, {"w": np.zeros(2)}, meta={"epoch": 4, "note": "x"})
    meta = load_meta(path)
    assert meta == {"epoch": 4, "note": "x"}


def test_mismatched_treedef_raises_value_error(tmp_path):
    """Key-set mismatch is a ValueError (not a bare assert, which would
    vanish under `python -O`) naming the differing keys."""
    path = os.path.join(tmp_path, "ck")
    save_checkpoint(path, {"a": np.zeros(2), "b": np.ones(3)})
    with pytest.raises(ValueError, match="mismatch"):
        restore_checkpoint(path, {"a": np.zeros(2), "c": np.ones(3)})


def test_checkpoint_exists_false_for_missing(tmp_path):
    assert not checkpoint_exists(os.path.join(tmp_path, "nope"))


# ------------------------------------------------- DENSE server resume ---

@pytest.fixture(scope="module")
def federation():
    data = make_classification_data(
        0, num_classes=SCFG.num_classes, size=SCFG.image_size,
        ch=SCFG.in_ch, train_per_class=SCFG.train_per_class,
        test_per_class=SCFG.test_per_class)
    clients, _ = build_federation(jax.random.PRNGKey(0), SCFG, data)
    return clients


@pytest.mark.parametrize("loop_mode", ["python", "fused"])
def test_dense_resume_matches_uninterrupted(tmp_path, federation,
                                            loop_mode):
    """Kill the server loop mid-distillation (after the epoch-4
    checkpoint, mid-way to epoch 6), resume from the checkpoint: final
    student AND generator params are bit-identical to an uninterrupted
    run, for both epoch drivers."""
    scfg = dataclasses.replace(SCFG, loop_mode=loop_mode, loop_chunk=3)
    ck = os.path.join(tmp_path, f"ck_{loop_mode}")
    scfg_ck = dataclasses.replace(scfg, checkpoint_every=2,
                                  checkpoint_path=ck)
    s_full, g_full, _ = train_dense_server(jax.random.PRNGKey(7),
                                           federation, scfg)
    # killed run: stops after epoch 5; last checkpoint is epoch 4
    train_dense_server(jax.random.PRNGKey(7), federation, scfg_ck,
                       _stop_after_epoch=5)
    assert checkpoint_exists(ck)
    assert load_meta(ck)["epoch"] == 4
    s_res, g_res, hist = train_dense_server(jax.random.PRNGKey(7),
                                            federation, scfg_ck)
    _leaves_equal(s_res, s_full)
    _leaves_equal(g_res, g_full)
    # history covers only the post-resume epochs
    assert len(hist.dis_loss) == SCFG.epochs - 4


def test_resume_ignored_without_checkpoint_config(tmp_path, federation):
    """checkpoint_every=0 (default) never writes or reads state."""
    s_a, _, _ = train_dense_server(jax.random.PRNGKey(7), federation, SCFG)
    s_b, _, _ = train_dense_server(jax.random.PRNGKey(7), federation, SCFG)
    _leaves_equal(s_a, s_b)
    assert not os.listdir(tmp_path)
