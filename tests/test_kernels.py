"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (ref.py).

Kernels run in interpret mode on CPU (the TPU lowering is exercised by the
same pallas_call).

The distill_kl custom-VJP suite doubles as CI's ``kernel-grads`` matrix:
``KERNEL_GRAD_DTYPE`` / ``KERNEL_GRAD_BLOCKS`` (e.g. ``bfloat16`` /
``4x96``) restrict the parametrization to one matrix cell so each CI job
runs a focused slice; unset (local runs) the full sweep executes."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from _hyp import given, settings, st

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("B,Hq,Hkv,Sq,Sk,D,win,dtype", [
    (2, 4, 2, 64, 64, 32, 0, jnp.float32),
    (1, 4, 4, 128, 128, 16, 0, jnp.float32),
    (2, 8, 2, 64, 64, 32, 24, jnp.float32),
    (1, 2, 1, 32, 128, 64, 0, jnp.float32),     # cross Sq != Sk (decode tail)
    (1, 4, 2, 64, 64, 32, 0, jnp.bfloat16),
    (1, 2, 2, 64, 64, 128, 16, jnp.float32),
])
def test_flash_attention_vs_ref(B, Hq, Hkv, Sq, Sk, D, win, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hq, Sq, D), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, Sk, D), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, Sk, D), dtype)
    out = ops.flash_attention(q, k, v, window=win, block_q=32, block_k=32)
    want = ref.attention(q, k, v, window=win)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol)


@pytest.mark.parametrize("R,V,br,bv,dtype", [
    (8, 512, 4, 128, jnp.float32),
    (16, 4096, 8, 1024, jnp.float32),
    (4, 1000, 4, 500, jnp.float32),
    (8, 512, 8, 512, jnp.bfloat16),
    # ragged: V % bv != 0 and/or R % br != 0 (tail blocks masked in-kernel)
    (8, 384, 8, 100, jnp.float32),
    (10, 250, 4, 128, jnp.float32),
    (7, 300, 4, 96, jnp.float32),
])
def test_distill_kl_vs_ref(R, V, br, bv, dtype):
    ks = jax.random.split(KEY, 2)
    t = (jax.random.normal(ks[0], (R, V)) * 3).astype(dtype)
    s = (jax.random.normal(ks[1], (R, V)) * 3).astype(dtype)
    out = ops.distill_kl(t, s, br, bv)
    want = ref.distill_kl(t, s)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=tol)


# ---------------------------------------------- distill_kl custom VJP --
#
# The fused backward kernel (kernels/distill_kl.distill_kl_bwd) vs
# jax.grad of the materialized reference. CI's kernel-grads job runs one
# (dtype x block-shape) cell per matrix entry via the env vars below.

_GRAD_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}
_GRAD_BLOCKS = {"8x128": (8, 128), "4x96": (4, 96)}


def _grad_matrix():
    dt = os.environ.get("KERNEL_GRAD_DTYPE")
    bl = os.environ.get("KERNEL_GRAD_BLOCKS")
    dtypes = [dt] if dt else list(_GRAD_DTYPES)
    blocks = [bl] if bl else list(_GRAD_BLOCKS)
    return [(d, b) for d in dtypes for b in blocks]


def _vjp_pair(t, s, br, bv, g, **kw):
    _, pull = jax.vjp(lambda a, b: ops.distill_kl(a, b, br, bv, **kw), t, s)
    return pull(g)


@pytest.mark.parametrize("dtype_name,block_name", _grad_matrix())
@pytest.mark.parametrize("R,V", [(16, 512), (10, 384), (7, 250)])
def test_distill_kl_vjp_matches_ref_grads(dtype_name, block_name, R, V):
    dtype = _GRAD_DTYPES[dtype_name]
    br, bv = _GRAD_BLOCKS[block_name]
    ks = jax.random.split(KEY, 3)
    t = (jax.random.normal(ks[0], (R, V)) * 3).astype(dtype)
    s = (jax.random.normal(ks[1], (R, V)) * 3).astype(dtype)
    g = jax.random.normal(ks[2], (R,))          # non-uniform cotangent
    dt, ds = _vjp_pair(t, s, br, bv, g)
    dt_r, ds_r = ref.distill_kl_grads(t, s, g)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(dt, np.float32),
                               np.asarray(dt_r, np.float32), atol=tol)
    np.testing.assert_allclose(np.asarray(ds, np.float32),
                               np.asarray(ds_r, np.float32), atol=tol)


def test_distill_kl_vjp_neg_inf_padding_columns():
    """NEG_INF-padded vocab columns (ragged-vocab convention): zero KL
    contribution and exactly-zero gradients on the padded lanes."""
    from repro.kernels.distill_kl import NEG_INF
    R, V, real = 8, 320, 300
    ks = jax.random.split(KEY, 3)
    t = jax.random.normal(ks[0], (R, V)) * 3
    s = jax.random.normal(ks[1], (R, V)) * 3
    t = t.at[:, real:].set(NEG_INF)
    s = s.at[:, real:].set(NEG_INF)
    out = ops.distill_kl(t, s, 4, 128)
    want = ref.distill_kl(t[:, :real], s[:, :real])
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5)
    g = jax.random.normal(ks[2], (R,))
    dt, ds = _vjp_pair(t, s, 4, 128, g)
    dt_r, ds_r = ref.distill_kl_grads(t, s, g)
    np.testing.assert_allclose(np.asarray(dt), np.asarray(dt_r), atol=1e-5)
    np.testing.assert_allclose(np.asarray(ds), np.asarray(ds_r), atol=1e-5)
    assert float(jnp.max(jnp.abs(dt[:, real:]))) == 0.0
    assert float(jnp.max(jnp.abs(ds[:, real:]))) == 0.0


def test_distill_kl_vjp_extreme_logits():
    """±1e4 logits: the online-LSE stats and the streamed backward must
    stay finite and track the reference (f32 rounding at this scale is
    ~1e-3 absolute, identical for both formulations)."""
    ks = jax.random.split(KEY, 3)
    R, V = 8, 256
    t = jax.random.choice(ks[0], jnp.array([-1e4, 0.0, 1e4]), (R, V)) \
        + jax.random.normal(ks[1], (R, V))
    s = jnp.roll(t, 7, axis=1) + jax.random.normal(ks[2], (R, V))
    out = ops.distill_kl(t, s, 4, 64)
    want = ref.distill_kl(t, s)
    assert bool(jnp.all(jnp.isfinite(out)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-2)
    g = jnp.ones((R,)) / R
    dt, ds = _vjp_pair(t, s, 4, 64, g)
    dt_r, ds_r = ref.distill_kl_grads(t, s, g)
    assert bool(jnp.all(jnp.isfinite(dt))) and bool(jnp.all(jnp.isfinite(ds)))
    # dt entries are p * ((t - lse_t) - (s - lse_s) - KL): differences of
    # 1e4-scale f32 terms, so ~1e-3 relative agreement is the f32 floor
    np.testing.assert_allclose(np.asarray(dt), np.asarray(dt_r),
                               rtol=1e-3, atol=1e-2)
    np.testing.assert_allclose(np.asarray(ds), np.asarray(ds_r),
                               rtol=1e-3, atol=1e-2)


def test_distill_kl_vjp_without_teacher_grad():
    """with_teacher_grad=False: identical dL/ds, zeros dL/dt (the stream
    is skipped for stop-gradient'd teachers)."""
    ks = jax.random.split(KEY, 2)
    t = jax.random.normal(ks[0], (6, 130))
    s = jax.random.normal(ks[1], (6, 130))
    g = jnp.ones((6,))
    dt, ds = _vjp_pair(t, s, 4, 64, g, with_teacher_grad=False)
    _, ds_full = _vjp_pair(t, s, 4, 64, g)
    assert float(jnp.max(jnp.abs(dt))) == 0.0
    np.testing.assert_allclose(np.asarray(ds), np.asarray(ds_full), atol=0)


def test_distill_kl_forward_persists_stats():
    """return_stats=True: the persisted accumulators reconstruct the
    row log-sum-exps and the KL identity KL = S/Z_t - lse_t + lse_s."""
    from repro.kernels.distill_kl import distill_kl
    ks = jax.random.split(KEY, 2)
    t = jax.random.normal(ks[0], (8, 300)) * 3
    s = jax.random.normal(ks[1], (8, 300)) * 3
    kl, (mt, zt, st, ms, zs) = distill_kl(t, s, block_rows=4, block_v=128,
                                          interpret=True, return_stats=True)
    lse_t = mt + jnp.log(zt)
    lse_s = ms + jnp.log(zs)
    np.testing.assert_allclose(np.asarray(lse_t),
                               np.asarray(jax.nn.logsumexp(t, axis=-1)),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(lse_s),
                               np.asarray(jax.nn.logsumexp(s, axis=-1)),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(st / zt - lse_t + lse_s),
                               np.asarray(kl), atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 12), st.integers(2, 160), st.integers(1, 5),
       st.integers(1, 70), st.integers(0, 2 ** 31 - 1))
def test_distill_kl_vjp_property(R, V, br, bv, seed):
    """Property: for ANY (R, V, block) combination — divisible or not —
    fused forward and VJP match the materialized reference."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    t = jax.random.normal(ks[0], (R, V)) * 4
    s = jax.random.normal(ks[1], (R, V)) * 4
    g = jax.random.normal(ks[2], (R,))
    out = ops.distill_kl(t, s, br, bv)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.distill_kl(t, s)), atol=2e-5)
    dt, ds = _vjp_pair(t, s, br, bv, g)
    dt_r, ds_r = ref.distill_kl_grads(t, s, g)
    np.testing.assert_allclose(np.asarray(dt), np.asarray(dt_r), atol=2e-5)
    np.testing.assert_allclose(np.asarray(ds), np.asarray(ds_r), atol=2e-5)


@pytest.mark.parametrize("B,S,H,P,G,N,cl", [
    (2, 64, 4, 16, 1, 32, 16),
    (1, 128, 8, 32, 2, 16, 32),
    (1, 64, 4, 64, 1, 64, 64),
    (2, 96, 6, 16, 3, 8, 32),
])
def test_ssd_scan_vs_sequential_ref(B, S, H, P, G, N, cl):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    b = jax.random.normal(ks[3], (B, S, G, N)) * 0.3
    c = jax.random.normal(ks[4], (B, S, G, N)) * 0.3
    y, st = ops.ssd_scan(x, dt, a, b, c, chunk=cl)
    y2, st2 = ref.ssd(x, dt, a, b, c)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), atol=2e-3)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st2), atol=2e-3)


def test_ssd_scan_matches_model_chunked_impl():
    """Kernel vs the model-level chunked jnp implementation (third algo)."""
    from repro.models.ssm import ssd_chunked
    ks = jax.random.split(KEY, 5)
    B, S, H, P, G, N = 1, 64, 4, 16, 1, 32
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    b = jax.random.normal(ks[3], (B, S, G, N)) * 0.3
    c = jax.random.normal(ks[4], (B, S, G, N)) * 0.3
    y1, s1 = ops.ssd_scan(x, dt, a, b, c, chunk=16)
    y2, s2 = ssd_chunked(x, dt, a, b, c, chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=2e-3)
