"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (ref.py).

Kernels run in interpret mode on CPU (the TPU lowering is exercised by the
same pallas_call).

The custom-VJP suites (distill_kl, flash_attention, ssd_scan — the §9
kernel pairs) double as CI's ``kernel-grads`` matrix: ``KERNEL_GRAD_DTYPE``
/ ``KERNEL_GRAD_BLOCKS`` (e.g. ``bfloat16`` / ``4x96``) restrict the
parametrization to one matrix cell so each CI job runs a focused slice;
unset (local runs) the full sweep executes. The block-name axis maps to
per-kernel block geometries (`_ATTN_GRAD_BLOCKS` / `_SSD_GRAD_CHUNKS`) so
one matrix covers all three pairs."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import backend as B
from repro.kernels import ops, ref
from _hyp import given, settings, st

KEY = jax.random.PRNGKey(0)

# every ops.* call here pins its geometry through an explicit ExecPolicy
# (the legacy block/interpret/vjp_mode kwargs are on the PR 11 removal
# schedule — kernels/ops.py; the shim itself is pinned by
# tests/test_backend.py's shim-equivalence suite until then)
_POL = B.resolve_exec_policy(None)


def _attn_pol(bq, bk, mode="autodiff"):
    return _POL.override_blocks("flash_attention", block_q=bq,
                                block_k=bk).replace(kernel_vjp=mode)


def _ssd_pol(chunk, mode="autodiff"):
    return _POL.override_blocks("ssd_scan",
                                chunk=chunk).replace(kernel_vjp=mode)


def _kl_pol(br, bv):
    return _POL.override_blocks("distill_kl", block_rows=br, block_v=bv)


@pytest.mark.parametrize("B,Hq,Hkv,Sq,Sk,D,win,dtype", [
    (2, 4, 2, 64, 64, 32, 0, jnp.float32),
    (1, 4, 4, 128, 128, 16, 0, jnp.float32),
    (2, 8, 2, 64, 64, 32, 24, jnp.float32),
    (1, 2, 1, 32, 128, 64, 0, jnp.float32),     # cross Sq != Sk (decode tail)
    (1, 4, 2, 64, 64, 32, 0, jnp.bfloat16),
    (1, 2, 2, 64, 64, 128, 16, jnp.float32),
])
def test_flash_attention_vs_ref(B, Hq, Hkv, Sq, Sk, D, win, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hq, Sq, D), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, Sk, D), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, Sk, D), dtype)
    out = ops.flash_attention(q, k, v, window=win, policy=_attn_pol(32, 32))
    want = ref.attention(q, k, v, window=win)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol)


@pytest.mark.parametrize("R,V,br,bv,dtype", [
    (8, 512, 4, 128, jnp.float32),
    (16, 4096, 8, 1024, jnp.float32),
    (4, 1000, 4, 500, jnp.float32),
    (8, 512, 8, 512, jnp.bfloat16),
    # ragged: V % bv != 0 and/or R % br != 0 (tail blocks masked in-kernel)
    (8, 384, 8, 100, jnp.float32),
    (10, 250, 4, 128, jnp.float32),
    (7, 300, 4, 96, jnp.float32),
])
def test_distill_kl_vs_ref(R, V, br, bv, dtype):
    ks = jax.random.split(KEY, 2)
    t = (jax.random.normal(ks[0], (R, V)) * 3).astype(dtype)
    s = (jax.random.normal(ks[1], (R, V)) * 3).astype(dtype)
    out = ops.distill_kl(t, s, policy=_kl_pol(br, bv))
    want = ref.distill_kl(t, s)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=tol)


# ---------------------------------------------- distill_kl custom VJP --
#
# The fused backward kernel (kernels/distill_kl.distill_kl_bwd) vs
# jax.grad of the materialized reference. CI's kernel-grads job runs one
# (dtype x block-shape) cell per matrix entry via the env vars below.

_GRAD_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}
_GRAD_BLOCKS = {"8x128": (8, 128), "4x96": (4, 96)}


def _grad_matrix():
    dt = os.environ.get("KERNEL_GRAD_DTYPE")
    bl = os.environ.get("KERNEL_GRAD_BLOCKS")
    dtypes = [dt] if dt else list(_GRAD_DTYPES)
    blocks = [bl] if bl else list(_GRAD_BLOCKS)
    return [(d, b) for d in dtypes for b in blocks]


def _vjp_pair(t, s, br, bv, g, **kw):
    _, pull = jax.vjp(
        lambda a, b: ops.distill_kl(a, b, policy=_kl_pol(br, bv), **kw),
        t, s)
    return pull(g)


@pytest.mark.parametrize("dtype_name,block_name", _grad_matrix())
@pytest.mark.parametrize("R,V", [(16, 512), (10, 384), (7, 250)])
def test_distill_kl_vjp_matches_ref_grads(dtype_name, block_name, R, V):
    dtype = _GRAD_DTYPES[dtype_name]
    br, bv = _GRAD_BLOCKS[block_name]
    ks = jax.random.split(KEY, 3)
    t = (jax.random.normal(ks[0], (R, V)) * 3).astype(dtype)
    s = (jax.random.normal(ks[1], (R, V)) * 3).astype(dtype)
    g = jax.random.normal(ks[2], (R,))          # non-uniform cotangent
    dt, ds = _vjp_pair(t, s, br, bv, g)
    dt_r, ds_r = ref.distill_kl_grads(t, s, g)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(dt, np.float32),
                               np.asarray(dt_r, np.float32), atol=tol)
    np.testing.assert_allclose(np.asarray(ds, np.float32),
                               np.asarray(ds_r, np.float32), atol=tol)


def test_distill_kl_vjp_neg_inf_padding_columns():
    """NEG_INF-padded vocab columns (ragged-vocab convention): zero KL
    contribution and exactly-zero gradients on the padded lanes."""
    from repro.kernels.distill_kl import NEG_INF
    R, V, real = 8, 320, 300
    ks = jax.random.split(KEY, 3)
    t = jax.random.normal(ks[0], (R, V)) * 3
    s = jax.random.normal(ks[1], (R, V)) * 3
    t = t.at[:, real:].set(NEG_INF)
    s = s.at[:, real:].set(NEG_INF)
    out = ops.distill_kl(t, s, policy=_kl_pol(4, 128))
    want = ref.distill_kl(t[:, :real], s[:, :real])
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5)
    g = jax.random.normal(ks[2], (R,))
    dt, ds = _vjp_pair(t, s, 4, 128, g)
    dt_r, ds_r = ref.distill_kl_grads(t, s, g)
    np.testing.assert_allclose(np.asarray(dt), np.asarray(dt_r), atol=1e-5)
    np.testing.assert_allclose(np.asarray(ds), np.asarray(ds_r), atol=1e-5)
    assert float(jnp.max(jnp.abs(dt[:, real:]))) == 0.0
    assert float(jnp.max(jnp.abs(ds[:, real:]))) == 0.0


def test_distill_kl_vjp_extreme_logits():
    """±1e4 logits: the online-LSE stats and the streamed backward must
    stay finite and track the reference (f32 rounding at this scale is
    ~1e-3 absolute, identical for both formulations)."""
    ks = jax.random.split(KEY, 3)
    R, V = 8, 256
    t = jax.random.choice(ks[0], jnp.array([-1e4, 0.0, 1e4]), (R, V)) \
        + jax.random.normal(ks[1], (R, V))
    s = jnp.roll(t, 7, axis=1) + jax.random.normal(ks[2], (R, V))
    out = ops.distill_kl(t, s, policy=_kl_pol(4, 64))
    want = ref.distill_kl(t, s)
    assert bool(jnp.all(jnp.isfinite(out)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-2)
    g = jnp.ones((R,)) / R
    dt, ds = _vjp_pair(t, s, 4, 64, g)
    dt_r, ds_r = ref.distill_kl_grads(t, s, g)
    assert bool(jnp.all(jnp.isfinite(dt))) and bool(jnp.all(jnp.isfinite(ds)))
    # dt entries are p * ((t - lse_t) - (s - lse_s) - KL): differences of
    # 1e4-scale f32 terms, so ~1e-3 relative agreement is the f32 floor
    np.testing.assert_allclose(np.asarray(dt), np.asarray(dt_r),
                               rtol=1e-3, atol=1e-2)
    np.testing.assert_allclose(np.asarray(ds), np.asarray(ds_r),
                               rtol=1e-3, atol=1e-2)


def test_distill_kl_vjp_without_teacher_grad():
    """with_teacher_grad=False: identical dL/ds, zeros dL/dt (the stream
    is skipped for stop-gradient'd teachers)."""
    ks = jax.random.split(KEY, 2)
    t = jax.random.normal(ks[0], (6, 130))
    s = jax.random.normal(ks[1], (6, 130))
    g = jnp.ones((6,))
    dt, ds = _vjp_pair(t, s, 4, 64, g, with_teacher_grad=False)
    _, ds_full = _vjp_pair(t, s, 4, 64, g)
    assert float(jnp.max(jnp.abs(dt))) == 0.0
    np.testing.assert_allclose(np.asarray(ds), np.asarray(ds_full), atol=0)


def test_distill_kl_forward_persists_stats():
    """return_stats=True: the persisted accumulators reconstruct the
    row log-sum-exps and the KL identity KL = S/Z_t - lse_t + lse_s."""
    from repro.kernels.distill_kl import distill_kl
    ks = jax.random.split(KEY, 2)
    t = jax.random.normal(ks[0], (8, 300)) * 3
    s = jax.random.normal(ks[1], (8, 300)) * 3
    kl, (mt, zt, st, ms, zs) = distill_kl(t, s, block_rows=4, block_v=128,
                                          interpret=True, return_stats=True)
    lse_t = mt + jnp.log(zt)
    lse_s = ms + jnp.log(zs)
    np.testing.assert_allclose(np.asarray(lse_t),
                               np.asarray(jax.nn.logsumexp(t, axis=-1)),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(lse_s),
                               np.asarray(jax.nn.logsumexp(s, axis=-1)),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(st / zt - lse_t + lse_s),
                               np.asarray(kl), atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 12), st.integers(2, 160), st.integers(1, 5),
       st.integers(1, 70), st.integers(0, 2 ** 31 - 1))
def test_distill_kl_vjp_property(R, V, br, bv, seed):
    """Property: for ANY (R, V, block) combination — divisible or not —
    fused forward and VJP match the materialized reference."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    t = jax.random.normal(ks[0], (R, V)) * 4
    s = jax.random.normal(ks[1], (R, V)) * 4
    g = jax.random.normal(ks[2], (R,))
    out = ops.distill_kl(t, s, policy=_kl_pol(br, bv))
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.distill_kl(t, s)), atol=2e-5)
    dt, ds = _vjp_pair(t, s, br, bv, g)
    dt_r, ds_r = ref.distill_kl_grads(t, s, g)
    np.testing.assert_allclose(np.asarray(dt), np.asarray(dt_r), atol=2e-5)
    np.testing.assert_allclose(np.asarray(ds), np.asarray(ds_r), atol=2e-5)


@pytest.mark.parametrize("B,S,H,P,G,N,cl", [
    (2, 64, 4, 16, 1, 32, 16),
    (1, 128, 8, 32, 2, 16, 32),
    (1, 64, 4, 64, 1, 64, 64),
    (2, 96, 6, 16, 3, 8, 32),
])
def test_ssd_scan_vs_sequential_ref(B, S, H, P, G, N, cl):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    b = jax.random.normal(ks[3], (B, S, G, N)) * 0.3
    c = jax.random.normal(ks[4], (B, S, G, N)) * 0.3
    y, st = ops.ssd_scan(x, dt, a, b, c, policy=_ssd_pol(cl))
    y2, st2 = ref.ssd(x, dt, a, b, c)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), atol=2e-3)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st2), atol=2e-3)


def test_ssd_scan_matches_model_chunked_impl():
    """Kernel vs the model-level chunked jnp implementation (third algo)."""
    from repro.models.ssm import ssd_chunked
    ks = jax.random.split(KEY, 5)
    B, S, H, P, G, N = 1, 64, 4, 16, 1, 32
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    b = jax.random.normal(ks[3], (B, S, G, N)) * 0.3
    c = jax.random.normal(ks[4], (B, S, G, N)) * 0.3
    y1, s1 = ops.ssd_scan(x, dt, a, b, c, policy=_ssd_pol(16))
    y2, s2 = ssd_chunked(x, dt, a, b, c, chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=2e-3)


# ------------------------------------------- flash_attention custom VJP --
#
# The streaming backward kernels (kernels/flash_attention.flash_attention_bwd)
# vs jax.vjp of the materialized reference — CI's kernel-grads matrix runs
# one (dtype x block) cell per job via the env vars above. The block-name
# axis maps to attention tile shapes here (divisible AND ragged-vs-32
# geometries per cell).

_ATTN_GRAD_BLOCKS = {"8x128": (32, 32), "4x96": (32, 16)}

# (B, Hq, Hkv, Sq, Sk, window): GQA ratios, ragged tails, cross Sq != Sk,
# and a window shorter than the k-block (fully-masked dead blocks)
_ATTN_GRAD_SHAPES = [
    (1, 4, 2, 64, 64, 0),
    (1, 2, 2, 48, 48, 0),        # ragged vs 32-wide blocks
    (1, 4, 1, 40, 72, 16),       # 4:1 GQA + ragged + decode-style cross
    (2, 2, 2, 64, 64, 8),        # window < block: dead k-blocks
]


def _attn_vjp(q, k, v, g, win, bq, bk):
    f = lambda a, b, c: ops.flash_attention(
        a, b, c, window=win, policy=_attn_pol(bq, bk, "fused"))
    out, pull = jax.vjp(f, q, k, v)
    return out, pull(g)


@pytest.mark.parametrize("dtype_name,block_name", _grad_matrix())
@pytest.mark.parametrize("B,Hq,Hkv,Sq,Sk,win", _ATTN_GRAD_SHAPES)
def test_flash_attention_vjp_matches_ref_grads(dtype_name, block_name,
                                               B, Hq, Hkv, Sq, Sk, win):
    dtype = _GRAD_DTYPES[dtype_name]
    bq, bk = _ATTN_GRAD_BLOCKS[block_name]
    D = 16
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, Hq, Sq, D), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, Sk, D), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, Sk, D), dtype)
    g = jax.random.normal(ks[3], (B, Hq, Sq, D), dtype)  # non-uniform cotangent
    out, grads = _attn_vjp(q, k, v, g, win, bq, bk)
    want = ref.attention(q, k, v, window=win)
    grads_r = ref.attention_grads(q, k, v, g, window=win)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol)
    for got, ref_g in zip(grads, grads_r):
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(ref_g, np.float32), atol=tol)


def test_flash_attention_ragged_tails_no_longer_crash():
    """Regression: Sq/Sk not a block multiple used to hit the hard
    ``Sq % bq == 0 and Sk % bk == 0`` assert; now the tail blocks are
    masked in-kernel and match the oracle."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 2, 40, 16))
    k = jax.random.normal(ks[1], (1, 2, 40, 16))
    v = jax.random.normal(ks[2], (1, 2, 40, 16))
    out = ops.flash_attention(q, k, v, policy=_attn_pol(32, 32))
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.attention(q, k, v)), atol=1e-5)


def test_flash_attention_fully_masked_kblock_regression():
    """Regression for the dead-block bug: a k-block with every key masked
    used to add exp(NEG_INF - NEG_INF) = 1 per lane into l while
    m == NEG_INF. In the pure forward the inflation washed out of o once
    a live block arrived (alpha = exp(NEG_INF - m_real) underflows to 0),
    but it corrupted the *persisted* (m, l) statistic — the residual the
    streaming backward folds into lse and divides its recomputed p by —
    for any row with NO live key at all. The discriminating probe is
    therefore the stats: lse must be the exact live-mass logsumexp, and
    exactly NEG_INF (zero mass, provably zero backward contribution) for
    never-live rows; the unmasked formulation yields
    NEG_INF + log(n_dead_lanes) there instead."""
    from repro.kernels.flash_attention import NEG_INF, flash_attention
    ks = jax.random.split(KEY, 3)
    # (a) windowed geometry with dead blocks for late rows: forward and
    # stats must match the materialized oracle
    S, win, bk = 96, 8, 32
    q = jax.random.normal(ks[0], (1, 2, S, 16))
    k = jax.random.normal(ks[1], (1, 2, S, 16))
    v = jax.random.normal(ks[2], (1, 2, S, 16))
    out, _, lse = flash_attention(q, k, v, window=win, block_q=32,
                                  block_k=bk, interpret=True,
                                  return_stats=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.attention(q, k, v,
                                                        window=win)),
                               atol=1e-5)
    scores = np.einsum("bhsd,bhtd->bhst", np.asarray(q),
                       np.asarray(k)) / 4.0
    pos = np.arange(S)
    live = (pos[None, :] <= pos[:, None]) & (pos[:, None] - pos[None, :] < win)
    masked = np.where(live[None, None], scores, -np.inf)
    want_lse = np.log(np.sum(np.exp(masked), axis=-1))
    np.testing.assert_allclose(np.asarray(lse).reshape(1, 2, S), want_lse,
                               atol=1e-4)
    # (b) never-live rows (causal with Sq > Sk: q_pos < 0): l must be
    # EXACTLY zero mass -> lse pinned to NEG_INF, output exactly 0
    Sq, Sk = 8, 4
    q2 = jax.random.normal(ks[0], (1, 1, Sq, 16))
    k2 = jax.random.normal(ks[1], (1, 1, Sk, 16))
    v2 = jax.random.normal(ks[2], (1, 1, Sk, 16))
    out2, _, lse2 = flash_attention(q2, k2, v2, block_q=4, block_k=4,
                                    interpret=True, return_stats=True)
    dead = np.asarray(lse2).reshape(Sq)[:Sq - Sk]
    np.testing.assert_array_equal(dead, np.full(Sq - Sk, NEG_INF))
    assert float(jnp.max(jnp.abs(out2[:, :, :Sq - Sk]))) == 0.0


# -------------------------------------------------- ssd_scan custom VJP --
#
# The reversed-recurrence backward kernel (kernels/ssd_scan.ssd_scan_bwd)
# vs jax.vjp of the sequential reference, from per-chunk carried-state
# residuals. Same CI matrix; the block-name axis maps to chunk lengths
# (ragged and divisible cells).

_SSD_GRAD_CHUNKS = {"8x128": 32, "4x96": 16}

# (B, S, H, P, G, N, nonzero initial state)
_SSD_GRAD_SHAPES = [
    (1, 64, 4, 16, 2, 16, False),
    (1, 40, 2, 8, 1, 8, True),    # ragged tail chunk + state handoff
    (2, 48, 4, 16, 4, 8, True),   # G == H (rep 1) + ragged for cl=32
]


def _ssd_inputs(B, S, H, P, G, N, dtype, with_init):
    ks = jax.random.split(KEY, 8)
    x = jax.random.normal(ks[0], (B, S, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))).astype(dtype)
    a = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    b = (jax.random.normal(ks[3], (B, S, G, N)) * 0.3).astype(dtype)
    c = (jax.random.normal(ks[4], (B, S, G, N)) * 0.3).astype(dtype)
    s0 = (jax.random.normal(ks[5], (B, H, P, N)) * 0.5 if with_init
          else jnp.zeros((B, H, P, N), jnp.float32))
    gy = jax.random.normal(ks[6], (B, S, H, P))
    gs = jax.random.normal(ks[7], (B, H, P, N)) * 0.1
    return x, dt, a, b, c, s0, gy, gs


@pytest.mark.parametrize("dtype_name,block_name", _grad_matrix())
@pytest.mark.parametrize("B,S,H,P,G,N,init", _SSD_GRAD_SHAPES)
def test_ssd_scan_vjp_matches_ref_grads(dtype_name, block_name,
                                        B, S, H, P, G, N, init):
    dtype = _GRAD_DTYPES[dtype_name]
    cl = _SSD_GRAD_CHUNKS[block_name]
    x, dt, a, b, c, s0, gy, gs = _ssd_inputs(B, S, H, P, G, N, dtype, init)
    f = lambda *ar: ops.ssd_scan(*ar, policy=_ssd_pol(cl, "fused"))
    (y, st), pull = jax.vjp(f, x, dt, a, b, c, s0)
    yr, st_r = ref.ssd(x, dt, a, b, c, initial_state=s0)
    # bf16 grads additionally carry the output-cast quantization, hence
    # the relative term (both sides round, but at different points)
    tol, rtol = (1e-4, 0) if dtype == jnp.float32 else (5e-2, 2e-2)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), atol=tol,
                               rtol=rtol)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_r), atol=tol,
                               rtol=rtol)
    grads = pull((gy.astype(y.dtype), gs))
    grads_r = ref.ssd_grads(x, dt, a, b, c, s0, gy, gs)
    for got, ref_g in zip(grads, grads_r):
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(ref_g, np.float32), atol=tol,
                                   rtol=rtol)


def test_ssd_scan_ragged_tail_no_longer_crashes():
    """Regression: S not a chunk multiple used to hit the hard
    ``S % cl == 0`` assert; the masked tail chunk must contribute zero to
    the carried state (dt = 0 on masked lanes)."""
    x, dt, a, b, c, _, _, _ = _ssd_inputs(1, 40, 2, 8, 1, 8,
                                          jnp.float32, False)
    y, st = ops.ssd_scan(x, dt, a, b, c, policy=_ssd_pol(32))
    yr, st_r = ref.ssd(x, dt, a, b, c)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-3)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_r), atol=2e-3)


def test_ssd_scan_initial_state_regression():
    """Regression for the dropped-state bug: the kernel zeroed its state
    carry unconditionally, so a nonzero initial_state (prefill→decode
    handoff) silently fell back to a cold start while the ref.ssd oracle
    honored it."""
    x, dt, a, b, c, s0, _, _ = _ssd_inputs(1, 64, 2, 8, 1, 8,
                                           jnp.float32, True)
    y, st = ops.ssd_scan(x, dt, a, b, c, s0, policy=_ssd_pol(16))
    yr, st_r = ref.ssd(x, dt, a, b, c, initial_state=s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-3)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_r), atol=2e-3)
    # a cold start must now DISAGREE (the old kernel returned this)
    y0, _ = ops.ssd_scan(x, dt, a, b, c, policy=_ssd_pol(16))
    assert float(jnp.max(jnp.abs(y0 - y))) > 1e-3


def test_ssd_scan_prefill_decode_handoff():
    """Split a sequence at a non-chunk boundary and thread the carried
    state: kernel(first) + kernel(rest, initial_state=carried) must equal
    one full-sequence kernel pass."""
    x, dt, a, b, c, _, _, _ = _ssd_inputs(1, 56, 2, 8, 2, 8,
                                          jnp.float32, False)
    cut = 24
    y_full, st_full = ops.ssd_scan(x, dt, a, b, c, policy=_ssd_pol(16))
    y1, st1 = ops.ssd_scan(x[:, :cut], dt[:, :cut], a, b[:, :cut],
                           c[:, :cut], policy=_ssd_pol(16))
    y2, st2 = ops.ssd_scan(x[:, cut:], dt[:, cut:], a, b[:, cut:],
                           c[:, cut:], st1, policy=_ssd_pol(16))
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=2e-3)
    np.testing.assert_allclose(np.asarray(st2), np.asarray(st_full),
                               atol=2e-3)


def test_kernel_vjp_mode_ref_and_unknown():
    """"ref" routes to the oracles; unknown modes fail fast — including
    a hand-built policy carrying a bogus kernel_vjp (the wrappers
    re-validate, so a stale ExecPolicy can't silently fall through to
    the forward-kernel branch)."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 2, 32, 16))
    out = ops.flash_attention(q, q, q, policy=_POL.replace(kernel_vjp="ref"))
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.attention(q, q, q)), atol=0)
    with pytest.raises(ValueError, match="unknown kernel_vjp mode"):
        ops.flash_attention(q, q, q,
                            policy=_POL.replace(kernel_vjp="pallas"))
    x, dt, a, b, c, _, _, _ = _ssd_inputs(1, 32, 2, 8, 1, 8,
                                          jnp.float32, False)
    with pytest.raises(ValueError, match="unknown kernel_vjp mode"):
        ops.ssd_scan(x, dt, a, b, c, policy=_POL.replace(kernel_vjp="nope"))
