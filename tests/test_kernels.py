"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (ref.py).

Kernels run in interpret mode on CPU (the TPU lowering is exercised by the
same pallas_call)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("B,Hq,Hkv,Sq,Sk,D,win,dtype", [
    (2, 4, 2, 64, 64, 32, 0, jnp.float32),
    (1, 4, 4, 128, 128, 16, 0, jnp.float32),
    (2, 8, 2, 64, 64, 32, 24, jnp.float32),
    (1, 2, 1, 32, 128, 64, 0, jnp.float32),     # cross Sq != Sk (decode tail)
    (1, 4, 2, 64, 64, 32, 0, jnp.bfloat16),
    (1, 2, 2, 64, 64, 128, 16, jnp.float32),
])
def test_flash_attention_vs_ref(B, Hq, Hkv, Sq, Sk, D, win, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hq, Sq, D), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, Sk, D), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, Sk, D), dtype)
    out = ops.flash_attention(q, k, v, window=win, block_q=32, block_k=32)
    want = ref.attention(q, k, v, window=win)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol)


@pytest.mark.parametrize("R,V,br,bv,dtype", [
    (8, 512, 4, 128, jnp.float32),
    (16, 4096, 8, 1024, jnp.float32),
    (4, 1000, 4, 500, jnp.float32),
    (8, 512, 8, 512, jnp.bfloat16),
])
def test_distill_kl_vs_ref(R, V, br, bv, dtype):
    ks = jax.random.split(KEY, 2)
    t = (jax.random.normal(ks[0], (R, V)) * 3).astype(dtype)
    s = (jax.random.normal(ks[1], (R, V)) * 3).astype(dtype)
    out = ops.distill_kl(t, s, br, bv)
    want = ref.distill_kl(t, s)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=tol)


def test_distill_kl_custom_vjp_matches_ref_grads():
    ks = jax.random.split(KEY, 2)
    t = jax.random.normal(ks[0], (4, 64))
    s = jax.random.normal(ks[1], (4, 64))
    for argnum in (0, 1):
        g1 = jax.grad(lambda *a: jnp.mean(ops.distill_kl(*a, 4, 64)),
                      argnums=argnum)(t, s)
        g2 = jax.grad(lambda *a: jnp.mean(ref.distill_kl(*a)),
                      argnums=argnum)(t, s)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-6)


@pytest.mark.parametrize("B,S,H,P,G,N,cl", [
    (2, 64, 4, 16, 1, 32, 16),
    (1, 128, 8, 32, 2, 16, 32),
    (1, 64, 4, 64, 1, 64, 64),
    (2, 96, 6, 16, 3, 8, 32),
])
def test_ssd_scan_vs_sequential_ref(B, S, H, P, G, N, cl):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    b = jax.random.normal(ks[3], (B, S, G, N)) * 0.3
    c = jax.random.normal(ks[4], (B, S, G, N)) * 0.3
    y, st = ops.ssd_scan(x, dt, a, b, c, chunk=cl)
    y2, st2 = ref.ssd(x, dt, a, b, c)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), atol=2e-3)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st2), atol=2e-3)


def test_ssd_scan_matches_model_chunked_impl():
    """Kernel vs the model-level chunked jnp implementation (third algo)."""
    from repro.models.ssm import ssd_chunked
    ks = jax.random.split(KEY, 5)
    B, S, H, P, G, N = 1, 64, 4, 16, 1, 32
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    b = jax.random.normal(ks[3], (B, S, G, N)) * 0.3
    c = jax.random.normal(ks[4], (B, S, G, N)) * 0.3
    y1, s1 = ops.ssd_scan(x, dt, a, b, c, chunk=16)
    y2, s2 = ssd_chunked(x, dt, a, b, c, chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=2e-3)
