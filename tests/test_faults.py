"""Fault injection + admission control: the fault-tolerant one-shot round.

The load-bearing property (DESIGN.md §10): with
``upload_policy="quarantine"``, a federation where client k's upload is
dropped or corrupted produces BIT-IDENTICAL ensemble logits, FedAvg
params and DENSE stage-2 trajectories to a federation built without
client k. Admission decisions are host-side static masks, so quarantined
clients are statically sliced out of the grouped representation
(ensemble.apply_group_masks) — the surviving computation is literally
the same program on the same values as the without-k federation.

The chosen quarantined client never changes the group first-occurrence
order (removal of a group's *first* client reorders heterogeneous
federations; the equivalence there is float-tolerance, not bitwise — we
pin the bitwise claim on order-preserving drops).

CI's ``chaos`` job reruns this module across the fault-kind x policy
matrix under XLA_FLAGS=--xla_force_host_platform_device_count=8
(CHAOS_KIND / CHAOS_POLICY env), so the masked ensemble is exercised
through the genuinely-sharded psum teacher path; on the plain tier-1
host the mesh is degenerate and the same tests pin the routing.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_cifar import DenseExperimentConfig
from repro.core.dense import train_dense_server
from repro.core.ensemble import (apply_group_masks, ensemble_logits,
                                 grouped_ensemble_logits, split_clients,
                                 stack_grouped)
from repro.data import make_classification_data
from repro.fl import (CommLedger, Fault, QuorumError, UploadError,
                      admit_uploads, build_fault_plan, build_federation,
                      corrupt_params, dense_multi_round, fedavg,
                      fedavg_stacked, param_bytes)
from repro.fl.faults import apply_upload_faults
from repro.launch.mesh import make_client_mesh
from repro.models.cnn import CNNSpec, cnn_init

SCFG = DenseExperimentConfig(
    n_clients=3, alpha=0.5, local_epochs=2, batch_size=16, num_classes=4,
    image_size=8, in_ch=1, train_per_class=37, test_per_class=8,
    client_kinds=("cnn1",) * 3, global_kind="cnn1", width=0.25, nz=16,
    t_g=1, epochs=2, synth_batch=16)

# CI chaos matrix: parametrize the injected kind/policy from env so one
# test module covers the whole fault-kind x policy grid
CHAOS_KIND = os.environ.get("CHAOS_KIND", "drop")
CHAOS_POLICY = os.environ.get("CHAOS_POLICY", "quarantine")


def _data(seed=0, scfg=SCFG):
    return make_classification_data(
        seed, num_classes=scfg.num_classes, size=scfg.image_size,
        ch=scfg.in_ch, train_per_class=scfg.train_per_class,
        test_per_class=scfg.test_per_class)


def _leaves_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _x(batch=4, size=8, ch=1, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((batch, size, size, ch))
                       .astype(np.float32))


@pytest.fixture(scope="module")
def healthy():
    """One healthy 3-client federation per engine (module-cached)."""
    data = _data()
    out = {}
    for mode in ("python", "grouped"):
        scfg = dataclasses.replace(SCFG, client_loop_mode=mode)
        out[mode] = build_federation(jax.random.PRNGKey(0), scfg, data)[0]
    return data, out


# ------------------------------------------------------------ fault plan ---

def test_fault_plan_deterministic_and_seeded():
    scfg = dataclasses.replace(SCFG, n_clients=10, dropout_frac=0.3,
                               fault_seed=4, fault_plan=((1, "nan"),))
    p1 = build_fault_plan(scfg)
    p2 = build_fault_plan(scfg)
    assert p1.keys() == p2.keys() and p1[1].kind == "nan"
    drops = [i for i, f in p1.items() if f.kind == "drop"]
    assert len(drops) == 3 and 1 not in drops
    # different seed, different victims (overwhelmingly likely)
    p3 = build_fault_plan(dataclasses.replace(scfg, fault_seed=5))
    assert p1.keys() != p3.keys() or \
        [p1[k].kind for k in sorted(p1)] != [p3[k].kind for k in sorted(p3)]


def test_fault_plan_validates():
    with pytest.raises(ValueError):
        Fault(client=0, kind="gremlin")
    with pytest.raises(ValueError):
        build_fault_plan(dataclasses.replace(SCFG, fault_plan=((7, "drop"),)))
    with pytest.raises(ValueError):
        build_fault_plan(dataclasses.replace(SCFG, dropout_frac=1.5))


def test_corrupt_params_kinds():
    spec = CNNSpec(kind="cnn1", num_classes=4, in_ch=1, width=0.25,
                   image_size=8)
    p = cnn_init(jax.random.PRNGKey(0), spec)
    key = jax.random.PRNGKey(1)
    nan_p = corrupt_params(p, "nan", key=key)
    assert any(np.isnan(np.asarray(l)).any() for l in jax.tree.leaves(nan_p))
    inf_p = corrupt_params(p, "inf", key=key)
    assert any(np.isinf(np.asarray(l)).any() for l in jax.tree.leaves(inf_p))
    sf = corrupt_params(p, "signflip", key=key)
    _leaves_equal(sf, jax.tree.map(lambda a: -a, p))
    noisy = corrupt_params(p, "noise", key=key, scale=10.0)
    assert all(np.isfinite(np.asarray(l)).all()
               for l in jax.tree.leaves(noisy))
    # seeded: same key -> same corruption
    _leaves_equal(noisy, corrupt_params(p, "noise", key=key, scale=10.0))


# ---------------------------------------------------------------- ledger ---

def test_ledger_rejects_bad_direction_and_kind():
    led = CommLedger()
    with pytest.raises(ValueError):
        led.record("sideways", "c0", 1, "x")
    with pytest.raises(ValueError):
        led.record("up", "c0", 1, "x", kind="vanished")


def test_ledger_fault_accounting(healthy):
    """Every client gets exactly one up event per round; dropped bytes
    leave uplink_bytes; a rejected upload keeps its delivered bytes plus
    a zero-byte rejected marker; rounds stays 1."""
    data, _ = healthy
    scfg = dataclasses.replace(SCFG, fault_plan=((1, "nan"), (2, "drop")),
                               quorum=0.3)
    led = CommLedger()
    clients, _ = build_federation(jax.random.PRNGKey(0), scfg, data,
                                  ledger=led)
    per_kind = {k: led.kinds(k) for k in ("delivered", "dropped",
                                          "delayed", "rejected")}
    assert [e["who"] for e in per_kind["dropped"]] == ["client2"]
    assert [e["who"] for e in per_kind["rejected"]] == ["client1"]
    assert sorted(e["who"] for e in per_kind["delivered"]) == \
        ["client0", "client1"]
    assert led.rounds == 1 and led.downlink_bytes == 0
    assert led.uplink_bytes == sum(e["bytes"]
                                   for e in per_kind["delivered"])
    assert all(e["bytes"] == 0 for e in per_kind["rejected"])


def test_no_fault_path_ledger_unchanged(healthy):
    """Without a fault plan the events list is exactly the pre-fault
    format (all delivered, one per client, trained bytes)."""
    data, fed = healthy
    led = CommLedger()
    clients, _ = build_federation(jax.random.PRNGKey(0), SCFG, data,
                                  ledger=led)
    assert [e["kind"] for e in led.events] == ["delivered"] * 3
    assert led.uplink_bytes == sum(param_bytes(c.params) for c in clients)
    assert not hasattr(clients, "survivor_mask")


# --------------------------------------- quarantine ≡ removal (bitwise) ---

@pytest.mark.parametrize("engine", ["python", "grouped"])
def test_quarantine_equivalent_to_removal(healthy, engine):
    """Drop/corrupt client 2 under quarantine: ensemble logits, FedAvg
    and the DENSE stage-2 student are bit-identical to the same
    federation with client 2 removed — both client engines."""
    data, fed = healthy
    kind = CHAOS_KIND if CHAOS_KIND in ("drop", "nan", "inf") else "drop"
    scfg = dataclasses.replace(SCFG, client_loop_mode=engine,
                               fault_plan=((2, kind),),
                               upload_policy="quarantine")
    cq, _ = build_federation(jax.random.PRNGKey(0), scfg, data)
    assert cq.quarantined.keys() == {2}
    ref = [c for i, c in enumerate(fed[engine]) if i != 2]

    x = _x()
    gs_q, gp_q = stack_grouped(cq)
    gs_r, gp_r = stack_grouped(ref)
    assert [(s.kind, n) for s, n in gs_q] == [(s.kind, n)
                                              for s, n in gs_r]
    np.testing.assert_array_equal(
        np.asarray(grouped_ensemble_logits(gs_q, gp_q, x)),
        np.asarray(grouped_ensemble_logits(gs_r, gp_r, x)))

    _leaves_equal(fedavg(cq), fedavg(ref))

    s_q, _, _ = train_dense_server(jax.random.PRNGKey(3), cq, scfg)
    s_r, _, _ = train_dense_server(jax.random.PRNGKey(3), ref, scfg)
    _leaves_equal(s_q, s_r)


def test_quarantine_equivalence_sharded(healthy):
    """The masked ensemble through the shard_map psum teacher: the
    surviving group size re-checks divisibility, and where it shards the
    result is bit-identical to the without-k federation evaluated on the
    same mesh (degenerate 1-device mesh on the plain tier-1 host; the
    chaos CI env provides 8 host devices)."""
    data = _data()
    scfg5 = dataclasses.replace(SCFG, n_clients=5,
                                client_kinds=("cnn1",) * 5, local_epochs=1)
    clients, _ = build_federation(jax.random.PRNGKey(0), scfg5, data)
    scfg_f = dataclasses.replace(scfg5, fault_plan=((3, "drop"),))
    cq, _ = build_federation(jax.random.PRNGKey(0), scfg_f, data)
    ref = [c for i, c in enumerate(clients) if i != 3]
    # 4 survivors: take at most 4 devices so the clients axis divides
    devs = jax.devices()[:min(4, len(jax.devices()))]
    if len(devs) == 3:
        devs = devs[:2]
    mesh = make_client_mesh(devices=devs)
    x = _x()
    gs_q, gp_q = stack_grouped(cq)
    gs_r, gp_r = stack_grouped(ref)
    np.testing.assert_array_equal(
        np.asarray(grouped_ensemble_logits(gs_q, gp_q, x, mesh=mesh)),
        np.asarray(grouped_ensemble_logits(gs_r, gp_r, x, mesh=mesh)))
    # and the sharded masked teacher matches the unsharded reference
    np.testing.assert_allclose(
        np.asarray(grouped_ensemble_logits(gs_q, gp_q, x, mesh=mesh)),
        np.asarray(ensemble_logits(*split_clients(ref), x)), atol=2e-5)


def test_heterogeneous_quarantine_float_equivalence(healthy):
    """Removing a client that changes group first-occurrence order keeps
    float-tolerance equivalence (bitwise is only pinned for
    order-preserving drops)."""
    data = _data()
    scfg = dataclasses.replace(SCFG, client_kinds=("cnn1", "cnn2", "cnn1"))
    clients, _ = build_federation(jax.random.PRNGKey(0), scfg, data)
    scfg_f = dataclasses.replace(scfg, fault_plan=((0, "drop"),))
    cq, _ = build_federation(jax.random.PRNGKey(0), scfg_f, data)
    ref = [c for i, c in enumerate(clients) if i != 0]
    x = _x()
    lq = grouped_ensemble_logits(*stack_grouped(cq), x)
    lr = grouped_ensemble_logits(*stack_grouped(ref), x)
    np.testing.assert_allclose(np.asarray(lq), np.asarray(lr), atol=1e-5)


def test_fedavg_stacked_survivor_mask():
    spec = CNNSpec(kind="cnn1", num_classes=4, in_ch=1, width=0.25,
                   image_size=8)
    params = [cnn_init(jax.random.PRNGKey(i), spec) for i in range(3)]
    stacked = jax.tree.map(lambda *a: jnp.stack(a), *params)
    mask = np.array([True, False, True])
    got = fedavg_stacked(stacked, [10, 5, 20], survivor_mask=mask)
    want = fedavg_stacked(
        jax.tree.map(lambda *a: jnp.stack(a), params[0], params[2]),
        [10, 20])
    _leaves_equal(got, want)
    # quarantined clients are exempt from the n_data positivity check
    got2 = fedavg_stacked(stacked, [10, 0, 20], survivor_mask=mask)
    _leaves_equal(got2, want)
    with pytest.raises(ValueError):
        fedavg_stacked(stacked, [10, 5, 20],
                       survivor_mask=np.zeros(3, bool))


# ------------------------------------------------- policies and quorum ---

def test_strict_policy_raises(healthy):
    data, _ = healthy
    kind = CHAOS_KIND if CHAOS_KIND in ("nan", "inf", "drop") else "nan"
    scfg = dataclasses.replace(SCFG, fault_plan=((1, kind),),
                               upload_policy="strict")
    if kind == "drop":
        # a missing upload is not a *rejected* upload: strict only
        # raises on admitted-then-failed screens; drop quarantines
        cq, _ = build_federation(jax.random.PRNGKey(0), scfg, data)
        assert cq.quarantined.keys() == {1}
    else:
        with pytest.raises(UploadError):
            build_federation(jax.random.PRNGKey(0), scfg, data)


def test_quorum_aborts_loudly(healthy):
    data, _ = healthy
    scfg = dataclasses.replace(SCFG, fault_plan=((0, "drop"), (1, "drop")),
                               quorum=0.5)
    with pytest.raises(QuorumError, match="quorum"):
        build_federation(jax.random.PRNGKey(0), scfg, data)
    # quorum=0.3 tolerates losing 2 of 3
    cq, _ = build_federation(
        jax.random.PRNGKey(0), dataclasses.replace(scfg, quorum=0.3), data)
    assert int(cq.survivor_mask.sum()) == 1


def test_norm_screen_catches_noise_not_signflip(healthy):
    """The MAD norm screen flags a scaled-noise Byzantine upload in a
    5-client cohort; a sign flip is norm-preserving and passes — the
    documented detection gap."""
    data = _data()
    scfg5 = dataclasses.replace(SCFG, n_clients=5,
                                client_kinds=("cnn1",) * 5, local_epochs=1,
                                norm_screen=6.0)
    noisy = dataclasses.replace(scfg5, fault_plan=((2, "noise", 50.0),))
    cn, _ = build_federation(jax.random.PRNGKey(0), noisy, data)
    assert 2 in cn.quarantined and "outlier" in cn.quarantined[2]
    flipped = dataclasses.replace(scfg5, fault_plan=((2, "signflip"),))
    cs, _ = build_federation(jax.random.PRNGKey(0), flipped, data)
    assert cs.quarantined == {}


def test_cos_screen_catches_signflip():
    """The opt-in leave-one-out cosine screen closes the norm screen's
    sign-flip gap (DESIGN.md §10): trained honest clients cluster
    directionally (BN scales and shared curvature push their cosine to
    the leave-one-out cohort mean well above 0) while a negated upload
    points away from all of them."""
    data = _data()
    scfg5 = dataclasses.replace(SCFG, n_clients=5,
                                client_kinds=("cnn1",) * 5, local_epochs=1,
                                cos_screen=0.0)
    flipped = dataclasses.replace(scfg5, fault_plan=((2, "signflip"),))
    cs, _ = build_federation(jax.random.PRNGKey(0), flipped, data)
    assert set(cs.quarantined) == {2}
    assert "direction outlier" in cs.quarantined[2]
    # the same screen passes an all-honest federation untouched
    ch, _ = build_federation(
        jax.random.PRNGKey(0),
        dataclasses.replace(scfg5, cos_screen=None), data)
    ch = admit_uploads(ch, scfg=scfg5)
    assert ch.quarantined == {}


def test_direction_screen_skips_small_cohorts():
    """< 5 candidates per architecture cohort: the screen abstains (a
    tiny cohort's mean direction is noise, not a defense) — even for a
    blatant flip."""
    from repro.core.ensemble import Client
    from repro.fl import direction_outliers
    from repro.models.cnn import CNNSpec, cnn_init
    spec = CNNSpec(kind="cnn1", num_classes=4, in_ch=1, width=0.25,
                   image_size=8)
    base = cnn_init(jax.random.PRNGKey(0), spec)
    clients = [Client(spec=spec, params=base, n_data=10) for _ in range(3)]
    clients.append(Client(
        spec=spec, params=jax.tree.map(lambda a: -a, base), n_data=10))
    assert direction_outliers(clients, list(range(4)), 0.0) == {}


def test_admission_policy_matrix(healthy):
    """The CI chaos matrix entry point: inject CHAOS_KIND under
    CHAOS_POLICY and assert the federation either heals (quarantine
    masks out the victim; the DENSE round trains finite) or aborts
    loudly (strict + a corrupt upload)."""
    data, _ = healthy
    scfg = dataclasses.replace(
        SCFG, fault_plan=((2, CHAOS_KIND, 50.0),),
        upload_policy=CHAOS_POLICY,
        norm_screen=6.0 if CHAOS_KIND == "noise" else 0.0)
    if CHAOS_POLICY == "strict" and CHAOS_KIND in ("nan", "inf"):
        with pytest.raises(UploadError):
            build_federation(jax.random.PRNGKey(0), scfg, data)
        return
    cq, _ = build_federation(jax.random.PRNGKey(0), scfg, data)
    if CHAOS_KIND in ("drop", "delay", "nan", "inf"):
        assert 2 in cq.quarantined
    stu, _, _ = train_dense_server(jax.random.PRNGKey(3), cq, scfg)
    assert all(np.isfinite(np.asarray(l)).all()
               for l in jax.tree.leaves(stu))


# ----------------------------------------------- multiround fault carry ---

@pytest.mark.slow
def test_multiround_delay_carries_upload_forward():
    """A round-0 delay fault withholds the upload and presents the stale
    round-0 params as the round-1 upload; every round's ledger still has
    one up event per client and the run stays finite."""
    scfg = dataclasses.replace(
        SCFG, n_clients=2, client_kinds=("cnn1",) * 2,
        fault_plan=(Fault(client=1, kind="delay", round=0),), quorum=0.4)
    data = _data(5, scfg)
    led = CommLedger()
    gp, spec, _ = dense_multi_round(jax.random.PRNGKey(6), scfg, data,
                                    rounds=2, ledger=led)
    kinds = {(e["who"], e["what"]): e["kind"] for e in led.events
             if e["dir"] == "up"}
    assert kinds[("client1", "round0-model-upload")] == "delayed"
    assert kinds[("client1", "round1-model-upload")] == "delivered"
    assert led.rounds == 2
    assert all(np.isfinite(np.asarray(l)).all()
               for l in jax.tree.leaves(gp))


# ----------------------------------------------------- nan self-healing ---

@pytest.mark.parametrize("policy", ["skip", "rollback"])
def test_nan_policy_recovers_poisoned_epoch(healthy, policy):
    """An injected non-finite loss epoch (NaN latent batch) does not
    derail stage 2: the run completes with finite params, training
    resumes with finite losses on the next epoch, and skip == rollback
    to float tolerance (identical up to guard-recompilation noise)."""
    data, fed = healthy
    scfg = dataclasses.replace(SCFG, epochs=5, nan_policy=policy)
    stu, gen, hist = train_dense_server(jax.random.PRNGKey(3),
                                        fed["grouped"], scfg,
                                        _poison_epochs=[2])
    assert not np.isfinite(hist.dis_loss[2])          # fault was real
    assert np.isfinite(hist.gen_loss[3]) and np.isfinite(hist.dis_loss[3])
    for tree in (stu, gen):
        assert all(np.isfinite(np.asarray(l)).all()
                   for l in jax.tree.leaves(tree))


def test_nan_policy_raise_default(healthy):
    data, fed = healthy
    scfg = dataclasses.replace(SCFG, epochs=4)
    with pytest.raises(FloatingPointError, match="non-finite"):
        train_dense_server(jax.random.PRNGKey(3), fed["grouped"], scfg,
                           _poison_epochs=[1])
    with pytest.raises(ValueError):
        train_dense_server(
            jax.random.PRNGKey(3), fed["grouped"],
            dataclasses.replace(scfg, nan_policy="ostrich"))


def test_nan_skip_matches_rollback(healthy):
    data, fed = healthy
    scfg = dataclasses.replace(SCFG, epochs=5)
    s_skip, _, _ = train_dense_server(
        jax.random.PRNGKey(3), fed["grouped"],
        dataclasses.replace(scfg, nan_policy="skip"), _poison_epochs=[2])
    s_roll, _, _ = train_dense_server(
        jax.random.PRNGKey(3), fed["grouped"],
        dataclasses.replace(scfg, nan_policy="rollback"),
        _poison_epochs=[2])
    diff = max(float(jnp.max(jnp.abs(a - b))) for a, b in
               zip(jax.tree.leaves(s_skip), jax.tree.leaves(s_roll)))
    assert diff < 1e-4


# ------------------------------------------------------ mask plumbing ---

def test_apply_group_masks_static_slicing():
    spec = CNNSpec(kind="cnn1", num_classes=4, in_ch=1, width=0.25,
                   image_size=8)
    params = [cnn_init(jax.random.PRNGKey(i), spec) for i in range(3)]
    stacked = jax.tree.map(lambda *a: jnp.stack(a), *params)
    gspecs, gparams = apply_group_masks(
        ((spec, 3),), [stacked], [np.array([True, False, True])])
    assert gspecs == ((spec, 2),)
    _leaves_equal(gparams[0],
                  jax.tree.map(lambda *a: jnp.stack(a), params[0],
                               params[2]))
    # reduced-to-one group becomes a flat singleton
    gspecs1, gparams1 = apply_group_masks(
        ((spec, 3),), [stacked], [np.array([False, True, False])])
    assert gspecs1 == ((spec, 1),)
    _leaves_equal(gparams1[0], params[1])
    with pytest.raises(ValueError):
        apply_group_masks(((spec, 3),), [stacked],
                          [np.array([False, False, False])])


def test_admit_uploads_direct_quarantine_reasons():
    """admit_uploads is callable outside build_federation: hand it a
    federation with a NaN'd client and read the quarantine verdicts."""
    spec = CNNSpec(kind="cnn1", num_classes=4, in_ch=1, width=0.25,
                   image_size=8)
    from repro.core.ensemble import Client
    clients = [Client(spec=spec,
                      params=cnn_init(jax.random.PRNGKey(i), spec),
                      n_data=10) for i in range(3)]
    clients[1] = Client(spec=spec,
                        params=jax.tree.map(
                            lambda a: jnp.full_like(a, jnp.nan),
                            clients[1].params), n_data=10)
    out = admit_uploads(clients, upload_policy="quarantine", quorum=0.5)
    assert out.quarantined.keys() == {1}
    assert "non-finite" in out.quarantined[1]
    assert list(out.survivor_mask) == [True, False, True]
    # quarantined slot is zero-filled in the raw (unmasked) stack
    raw = stack_grouped(out, apply_masks=False)
    assert all(np.all(np.asarray(l)[1] == 0)
               for l in jax.tree.leaves(raw[1][0]))
