"""Serving engine tests (DESIGN.md §12).

The contract under test: the paged block-pool engine with continuous
batching produces EXACTLY the tokens of the sequential batch-1
dense-cache reference, request by request, whatever shares its decode
batch — across attention (llama), pure-SSM (mamba2) and hybrid (zamba2)
families, under slot recycling, pool exhaustion and mid-flight arrivals.
Plus the paged decode-attention kernel vs its oracle over ragged
block-table tails, and the block allocator's invariants.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import backend as B
from repro.configs.base import get_smoke_config
from repro.kernels import paged_attention as PK
from repro.kernels import ref
from repro.launch import paging as PG
from repro.launch.engine import ServeEngine, engine_keys
from repro.launch.serve import serve
from repro.models import transformer as T

ARCHS = ["llama3.2-3b", "mamba2-130m", "zamba2-7b"]

# ragged on purpose: three distinct prompt lengths AND gen budgets, so
# requests start and finish at different scheduler iterations
_PROMPTS = [(5, 6), (9, 4), (12, 7)]          # (prompt_len, max_new)


def _mk(arch, seed=0):
    cfg = get_smoke_config(arch)
    k_init, k_prompt, _ = engine_keys(seed)
    params = T.init_model(k_init, cfg)
    prompts = [np.asarray(jax.random.randint(
        jax.random.fold_in(k_prompt, i), (p,), 0, cfg.vocab_size), np.int32)
        for i, (p, _) in enumerate(_PROMPTS)]
    return cfg, params, prompts


def _run(cfg, params, prompts, mode, *, max_reqs=2, seed=0, sampling=None,
         **kw):
    eng = ServeEngine(cfg, params, mode=mode, max_reqs=max_reqs,
                      max_len=max(p + g for p, g in _PROMPTS), seed=seed,
                      **kw)
    sampling = sampling or [None] * len(prompts)
    rids = [eng.submit(pr, max_new=g, sampling=s)
            for pr, (_, g), s in zip(prompts, _PROMPTS, sampling)]
    out = eng.drain()
    return [out[r] for r in rids], eng


# -------------------------------------------- paged ≡ dense, per family --

@pytest.mark.parametrize("arch", ARCHS)
def test_paged_equals_dense(arch):
    """Continuous paged decode == sequential dense reference, token for
    token, with ragged prompts and 3 requests sharing 2 slots (so the
    third request recycles a freed slot + released blocks)."""
    cfg, params, prompts = _mk(arch)
    dense, _ = _run(cfg, params, prompts, "dense")
    paged, eng = _run(cfg, params, prompts, "paged", max_reqs=2)
    for d, p in zip(dense, paged):
        np.testing.assert_array_equal(d, p)
    # every block returned to the pool after drain
    assert eng.allocator.n_free == eng.allocator.n_blocks - 1


def test_paged_kernel_path_end_to_end():
    """Same equivalence with the engine's ops.paged_attention routed to
    the Pallas kernel (cfg.kernel_vjp_mode='autodiff'; the CPU profile's
    interpret=True rides along) instead of the ref oracle."""
    cfg, params, prompts = _mk("llama3.2-3b")
    kcfg = cfg.replace(kernel_vjp_mode="autodiff")
    dense, _ = _run(cfg, params, prompts, "dense")
    paged, _ = _run(kcfg, params, prompts, "paged")
    for d, p in zip(dense, paged):
        np.testing.assert_array_equal(d, p)


# --------------------------------------- continuous ≡ sequential arrivals --

def test_continuous_equals_sequential_under_arrival_trace():
    """Fixed arrival trace: requests join a RUNNING decode batch at
    different steps (one of them temperature-sampled). Per-request token
    streams must equal the submit-everything-upfront sequential dense
    run — sampling is keyed by (rid, token_index), never by batch
    composition."""
    cfg, params, prompts = _mk("llama3.2-3b", seed=3)
    sampling = [None, {"temperature": 0.7}, None]

    seq, _ = _run(cfg, params, prompts, "dense", seed=3, sampling=sampling)

    eng = ServeEngine(cfg, params, mode="paged", max_reqs=3,
                      max_len=max(p + g for p, g in _PROMPTS), seed=3)
    r0 = eng.submit(prompts[0], max_new=_PROMPTS[0][1])
    eng.step(); eng.step()                       # r0 decoding alone
    r1 = eng.submit(prompts[1], max_new=_PROMPTS[1][1],
                    sampling=sampling[1])
    eng.step()                                   # r1 joins mid-flight
    r2 = eng.submit(prompts[2], max_new=_PROMPTS[2][1])
    out = eng.drain()
    for want, got in zip(seq, (out[r0], out[r1], out[r2])):
        np.testing.assert_array_equal(want, got)


# ----------------------------------------- pool exhaustion and recycling --

def test_pool_exhaustion_queues_then_recycles():
    """A pool sized for ONE worst-case request forces fully sequential
    admission: later submits queue (FIFO), each admission reuses the
    blocks the previous request released — and the tokens still match
    the roomy-pool run."""
    cfg, params, prompts = _mk("mamba2-130m")
    roomy, _ = _run(cfg, params, prompts, "paged", max_reqs=3)

    max_len = max(p + g for p, g in _PROMPTS)
    eng = ServeEngine(cfg, params, mode="paged", max_reqs=3,
                      max_len=max_len, page=4,
                      n_blocks=1 + PG.blocks_needed(max_len, 0, 4))
    rids = [eng.submit(pr, max_new=g)
            for pr, (_, g) in zip(prompts, _PROMPTS)]
    running_high = 0
    while any(eng.poll(r)["status"] != "done" for r in rids):
        eng.step()
        running_high = max(running_high, sum(
            1 for r in rids if eng.poll(r)["status"] == "running"))
    assert running_high == 1                     # never two in flight
    assert eng.allocator.n_free == eng.allocator.n_blocks - 1
    for want, r in zip(roomy, rids):
        np.testing.assert_array_equal(want, eng.poll(r)["tokens"])


def test_impossible_request_raises_not_hangs():
    """A request whose block budget exceeds the WHOLE pool can never be
    admitted — step() must raise (deadlock detection), not spin."""
    cfg, params, prompts = _mk("llama3.2-3b")
    eng = ServeEngine(cfg, params, mode="paged", max_reqs=2, max_len=32,
                      page=4, n_blocks=3)        # pool: 2 usable blocks
    eng.submit(prompts[0], max_new=12)           # needs 5 > 2 blocks
    with pytest.raises(RuntimeError, match="pool too small"):
        eng.step()


def test_submit_validation_and_poll_lifecycle():
    cfg, params, prompts = _mk("llama3.2-3b")
    eng = ServeEngine(cfg, params, mode="paged", max_reqs=2, max_len=16)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(np.zeros((0,), np.int32))
    with pytest.raises(ValueError, match="max_new"):
        eng.submit(prompts[0], max_new=0)
    with pytest.raises(ValueError, match="exceeds"):
        eng.submit(prompts[0], max_new=12)       # 5 + 12 > 16
    rid = eng.submit(prompts[0], max_new=2)
    assert eng.poll(rid)["status"] == "queued"
    eng.drain()
    done = eng.poll(rid)
    assert done["status"] == "done" and len(done["tokens"]) == 2
    assert done["latency_s"] >= 0.0


def test_block_allocator_invariants():
    a = PG.BlockAllocator(5)                     # blocks 1..4 usable
    assert a.n_free == 4
    got = a.alloc(3)
    assert got is not None and 0 not in got and len(set(got)) == 3
    assert a.alloc(2) is None and a.n_free == 1  # all-or-nothing
    a.release(got)
    assert a.n_free == 4
    with pytest.raises(ValueError, match="double free"):
        a.release(got)
    with pytest.raises(ValueError, match=">= 2"):
        PG.BlockAllocator(1)


def test_unsupported_family_falls_back_to_dense():
    """Sliding-window dense layouts aren't paged: mode auto-selects the
    sequential fallback, and forcing paged fails fast."""
    cfg, params, _ = _mk("llama3.2-3b")
    swcfg = cfg.replace(sliding_window=8)
    assert not PG.supports_paged(swcfg)
    eng = ServeEngine(swcfg, params, max_reqs=1, max_len=16)
    assert eng.mode == "dense"
    with pytest.raises(ValueError, match="paged mode unsupported"):
        ServeEngine(swcfg, params, mode="paged", max_reqs=1, max_len=16)


# --------------------------------- paged kernel vs oracle, ragged tails --

@pytest.mark.parametrize("page,m,seqs", [
    (8, 4, (1, 17, 32)),       # one token / mid-block tail / full table
    (8, 4, (8, 16, 24)),       # exact block boundaries
    (16, 2, (3, 31, 32)),
    (4, 7, (5, 13, 27)),       # odd page count, ragged everywhere
])
def test_paged_kernel_matches_oracle_ragged(page, m, seqs):
    """kernels.paged_attention (interpret) vs kernels.ref oracle across
    ragged block-table tails, GQA grouping included."""
    r, hq, hkv, d = len(seqs), 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(17), 3)
    n_blocks = 1 + r * m
    q = jax.random.normal(ks[0], (r, hq, d), jnp.float32)
    kp = jax.random.normal(ks[1], (n_blocks, page, hkv, d), jnp.float32)
    vp = jax.random.normal(ks[2], (n_blocks, page, hkv, d), jnp.float32)
    bt = (jnp.arange(r * m, dtype=jnp.int32) + 1).reshape(r, m)
    seq = jnp.asarray(seqs, jnp.int32)
    out = PK.paged_attention(q, kp, vp, bt, seq, interpret=True)
    want = ref.paged_attention(q, kp, vp, bt, seq)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-5)


def test_paged_kernel_null_row_is_zero_mass():
    """A seq_len of 0 (inactive scheduler slot pointing at block 0) must
    contribute exactly zero output — the masked p never touches pool
    garbage."""
    page, m = 8, 2
    q = jnp.ones((2, 2, 8), jnp.float32)
    pool = jnp.full((5, page, 1, 8), 7.5, jnp.float32)
    bt = jnp.asarray([[0, 0], [1, 2]], jnp.int32)
    seq = jnp.asarray([0, 5], jnp.int32)
    out = PK.paged_attention(q, pool, pool, bt, seq, interpret=True)
    np.testing.assert_array_equal(np.asarray(out[0]), 0.0)
    np.testing.assert_allclose(np.asarray(out[1]), 7.5, atol=1e-5)


def test_ops_paged_attention_policy_routing():
    """ops.paged_attention honors kernel_vjp='ref' (oracle) vs kernel
    routing and rejects unknown modes — same registry contract as the
    other kernels."""
    from repro.kernels import ops
    pol = B.resolve_exec_policy(None)
    q = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 16))
    pool = jax.random.normal(jax.random.PRNGKey(2), (5, 8, 2, 16))
    bt = jnp.asarray([[1, 2], [3, 4]], jnp.int32)
    seq = jnp.asarray([5, 11], jnp.int32)
    a = ops.paged_attention(q, pool, pool, bt, seq,
                            policy=pol.replace(kernel_vjp="ref"))
    b = ops.paged_attention(
        q, pool, pool, bt, seq,
        policy=pol.replace(kernel_vjp="autodiff", interpret=True))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    with pytest.raises(ValueError, match="unknown kernel_vjp mode"):
        ops.paged_attention(q, pool, pool, bt, seq,
                            policy=pol.replace(kernel_vjp="bogus"))


# ------------------------------------------------- serve() compat wrapper --

def test_serve_wrapper_compat_paged_equals_dense():
    """The thin serve() wrapper keeps the historical (tokens, stats)
    contract, and its paged/dense modes agree."""
    toks_p, stats_p = serve("llama3.2-3b", batch=2, prompt_len=8, gen=4,
                            smoke=True, mode="paged")
    toks_d, stats_d = serve("llama3.2-3b", batch=2, prompt_len=8, gen=4,
                            smoke=True, mode="dense")
    assert toks_p.shape == (2, 4) and toks_p.dtype == np.int32
    np.testing.assert_array_equal(toks_p, toks_d)
    for st in (stats_p, stats_d):
        assert set(st) >= {"prefill_s", "decode_s", "tok_per_s"}
        assert st["tok_per_s"] > 0
