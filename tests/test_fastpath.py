"""Equivalence tests for the perf fast paths: grouped-vmap ensemble,
fused (device-resident) epoch driver, and the batched evaluate.
Optimizations must never change the math."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_cifar import DenseExperimentConfig
from repro.core import losses as LS
from repro.core import train_dense_server
from repro.core.dense import _chunk_bounds, evaluate
from repro.core.ensemble import (Client, ensemble_logits,
                                 grouped_ensemble_logits, group_clients,
                                 split_clients, stack_grouped,
                                 stack_homogeneous)
from repro.models.cnn import CNNSpec, cnn_init, cnn_logits


def _mk_clients(kinds, seed0=0, **spec_kw):
    clients = []
    for i, k in enumerate(kinds):
        sp = CNNSpec(kind=k, num_classes=6, in_ch=3, width=0.5,
                     image_size=16, **spec_kw)
        clients.append(Client(spec=sp,
                              params=cnn_init(jax.random.PRNGKey(seed0 + i),
                                              sp)))
    return clients


# ------------------------------------------------------------- grouping ---

def test_group_clients_insertion_ordered_partition():
    kinds = ("cnn1", "cnn2", "cnn1", "wrn16_1", "cnn2", "cnn1")
    clients = _mk_clients(kinds)
    groups = group_clients(clients)
    # deterministic key order: first-occurrence order of each spec
    assert [spec.kind for spec, _ in groups] == ["cnn1", "cnn2", "wrn16_1"]
    assert [idx for _, idx in groups] == [(0, 2, 5), (1, 4), (3,)]
    # exact partition of client indices
    flat = [i for _, idx in groups for i in idx]
    assert sorted(flat) == list(range(len(kinds)))


def test_stack_homogeneous_via_groups():
    clients = _mk_clients(("cnn1",) * 3)
    spec, stacked = stack_homogeneous(clients)
    assert spec == clients[0].spec
    lead = jax.tree.leaves(stacked)[0].shape[0]
    assert lead == 3
    with pytest.raises(AssertionError):
        stack_homogeneous(_mk_clients(("cnn1", "cnn2")))


@pytest.mark.parametrize("batch", [8, 64])  # im2col and conv/scan regimes
def test_grouped_matches_unrolled_mixed_architectures(batch):
    kinds = ("cnn1", "cnn2", "cnn1", "wrn16_1", "cnn2")
    clients = _mk_clients(kinds)
    x = jax.random.normal(jax.random.PRNGKey(42), (batch, 16, 16, 3))
    specs, cparams = split_clients(clients)
    gspecs, gparams = stack_grouped(clients)
    assert sum(n for _, n in gspecs) == len(clients)
    ref, ref_stats = ensemble_logits(specs, cparams, x, with_bn_stats=True)
    got, got_stats = grouped_ensemble_logits(gspecs, gparams, x,
                                             with_bn_stats=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)
    # L_BN consumes the stats as an order-invariant sum over clients
    np.testing.assert_allclose(float(LS.bn_loss(got_stats)),
                               float(LS.bn_loss(ref_stats)), rtol=1e-4)


@pytest.mark.parametrize("kind", ["wrn16_1", "resnet18"])
def test_grouped_residual_stack_matches_unrolled(kind):
    """Size->=2 residual groups run the fused stacked forward
    (models.cnn._grouped_resnet) instead of vmapped cnn_apply: logits
    and L_BN inputs must match the unrolled reference — including the
    projection-shortcut stats slots and strided SAME conv geometry."""
    clients = _mk_clients((kind,) * 3)
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 16, 16, 3))
    specs, cparams = split_clients(clients)
    gspecs, gparams = stack_grouped(clients)
    ref, ref_stats = ensemble_logits(specs, cparams, x, with_bn_stats=True)
    got, got_stats = grouped_ensemble_logits(gspecs, gparams, x,
                                             with_bn_stats=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=5e-4)
    np.testing.assert_allclose(float(LS.bn_loss(got_stats)),
                               float(LS.bn_loss(ref_stats)), rtol=1e-3)
    # eval-only path (folded-BN branch) agrees too
    got_e = grouped_ensemble_logits(gspecs, gparams, x)
    ref_e = ensemble_logits(specs, cparams, x)
    np.testing.assert_allclose(np.asarray(got_e), np.asarray(ref_e),
                               atol=5e-4)


def test_grouped_matches_under_jit_homogeneous():
    clients = _mk_clients(("cnn1",) * 6)
    x = jax.random.normal(jax.random.PRNGKey(7), (16, 16, 16, 3))
    specs, cparams = split_clients(clients)
    gspecs, gparams = stack_grouped(clients)
    ref = jax.jit(lambda cp: ensemble_logits(specs, cp, x))(cparams)
    got = jax.jit(lambda gp: grouped_ensemble_logits(gspecs, gp, x))(gparams)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)


# ----------------------------------------------------------- epoch driver ---

SCFG = DenseExperimentConfig(
    n_clients=2, alpha=0.5, local_epochs=1, batch_size=32, num_classes=4,
    image_size=8, in_ch=1, train_per_class=16, test_per_class=8,
    client_kinds=("cnn1", "cnn1"), global_kind="cnn1", width=0.25, nz=16,
    t_g=2, epochs=5, synth_batch=16, s_steps=2, loop_chunk=2)


def test_chunk_bounds():
    assert _chunk_bounds(10, 4, 0) == [(0, 4), (4, 8), (8, 10)]
    assert _chunk_bounds(10, 4, 3) == [(0, 3), (3, 6), (6, 9), (9, 10)]
    assert _chunk_bounds(4, 8, 0) == [(0, 4)]


def test_fused_and_python_drivers_agree():
    """loop_mode='fused' must be a pure perf choice: same student params,
    same metric history as the per-step python driver for the same key
    (both consume the identical per-epoch key stream)."""
    clients = []
    sp = CNNSpec(kind="cnn1", num_classes=SCFG.num_classes, in_ch=SCFG.in_ch,
                 width=SCFG.width, image_size=SCFG.image_size)
    for i in range(2):
        clients.append(Client(spec=sp, params=cnn_init(jax.random.PRNGKey(i),
                                                       sp)))
    outs = {}
    for mode in ("python", "fused"):
        scfg = dataclasses.replace(SCFG, loop_mode=mode)
        stu, gen, hist = train_dense_server(jax.random.PRNGKey(3), clients,
                                            scfg)
        outs[mode] = (stu, gen, hist)
    stu_p, _, hist_p = outs["python"]
    stu_f, _, hist_f = outs["fused"]
    for a, b in zip(jax.tree.leaves(stu_p), jax.tree.leaves(stu_f)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)
    assert len(hist_f.gen_loss) == len(hist_p.gen_loss) == SCFG.epochs
    np.testing.assert_allclose(hist_f.gen_loss, hist_p.gen_loss, rtol=1e-3,
                               atol=1e-5)
    np.testing.assert_allclose(hist_f.dis_loss, hist_p.dis_loss, rtol=1e-3,
                               atol=1e-5)
    for pp, pf in zip(hist_p.gen_parts, hist_f.gen_parts):
        assert set(pp) == set(pf) == {"ce", "bn", "div"}
        for k in pp:
            np.testing.assert_allclose(pf[k], pp[k], rtol=1e-3, atol=1e-5)


def test_fused_eval_every_alignment():
    clients = []
    sp = CNNSpec(kind="cnn1", num_classes=SCFG.num_classes, in_ch=SCFG.in_ch,
                 width=SCFG.width, image_size=SCFG.image_size)
    for i in range(2):
        clients.append(Client(spec=sp, params=cnn_init(jax.random.PRNGKey(i),
                                                       sp)))
    seen = []

    def eval_fn(params, spec):
        seen.append(1)
        return 0.5

    scfg = dataclasses.replace(SCFG, loop_mode="fused", epochs=4,
                               loop_chunk=3)
    _, _, hist = train_dense_server(jax.random.PRNGKey(0), clients, scfg,
                                    eval_fn=eval_fn, eval_every=2)
    assert [e for e, _ in hist.acc] == [2, 4]


def test_unknown_loop_mode_raises():
    sp = CNNSpec(kind="cnn1", num_classes=4, in_ch=1, width=0.25,
                 image_size=8)
    clients = [Client(spec=sp, params=cnn_init(jax.random.PRNGKey(0), sp))]
    scfg = dataclasses.replace(SCFG, loop_mode="nope")
    with pytest.raises(ValueError):
        train_dense_server(jax.random.PRNGKey(0), clients, scfg)


# -------------------------------------------------------------- evaluate ---

def test_evaluate_matches_naive_loop():
    sp = CNNSpec(kind="cnn1", num_classes=5, in_ch=3, width=0.5,
                 image_size=8)
    params = cnn_init(jax.random.PRNGKey(0), sp)
    rng = np.random.default_rng(0)
    x = rng.standard_normal((37, 8, 8, 3)).astype(np.float32)
    y = rng.integers(0, 5, 37)
    # naive reference: per-batch python loop + per-batch sync
    correct = 0
    for i in range(0, 37, 16):
        lg = cnn_logits(params, sp, jnp.asarray(x[i:i + 16]))
        correct += int(jnp.sum(jnp.argmax(lg, -1) == jnp.asarray(y[i:i + 16])))
    want = correct / 37
    got = evaluate(params, sp, x, y, batch=16)
    assert got == pytest.approx(want)
    # batch larger than the dataset: single padded batch
    assert evaluate(params, sp, x, y, batch=512) == pytest.approx(want)
    # multiple device chunks (memory-bounded path): 3 batches, chunk=2
    assert evaluate(params, sp, x, y, batch=16,
                    device_batches=2) == pytest.approx(want)
