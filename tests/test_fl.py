"""FL-runtime invariants: Dirichlet partition, FedAvg, one-shot protocol,
communication accounting, heterogeneity support."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.ensemble import Client, ensemble_logits, split_clients
from repro.data.partition import dirichlet_partition
from repro.fl.fedavg import fedavg
from repro.fl.protocol import CommLedger, param_bytes
from repro.models.cnn import CNNSpec, cnn_init, cnn_logits

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


@given(st.integers(2, 8), st.sampled_from([0.1, 0.5, 5.0]),
       st.integers(0, 1000))
def test_dirichlet_partition_is_a_partition(n_clients, alpha, seed):
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 5, 300)
    parts = dirichlet_partition(labels, n_clients, alpha, seed=seed)
    allidx = np.concatenate(parts)
    assert len(allidx) == len(labels)
    assert set(allidx.tolist()) == set(range(len(labels)))  # exact cover
    assert min(len(p) for p in parts) >= 2


def test_dirichlet_skew_increases_as_alpha_decreases():
    labels = np.repeat(np.arange(10), 100)

    def skew(alpha):
        parts = dirichlet_partition(labels, 5, alpha, seed=0)
        # mean per-client entropy of the class distribution
        ent = []
        for p in parts:
            c = np.bincount(labels[p], minlength=10) / len(p)
            c = c[c > 0]
            ent.append(-(c * np.log(c)).sum())
        return np.mean(ent)

    assert skew(0.1) < skew(10.0)


def _tiny_clients(n=3, kind="cnn1", width=0.25, img=8):
    spec = CNNSpec(kind=kind, num_classes=4, in_ch=1, width=width,
                   image_size=img)
    out = []
    for i in range(n):
        p = cnn_init(jax.random.PRNGKey(i), spec)
        out.append(Client(spec=spec, params=p, n_data=10 * (i + 1)))
    return out


def test_fedavg_weighted_mean():
    clients = _tiny_clients(2)
    avg = fedavg(clients)
    w = [10 / 30, 20 / 30]
    leaf = lambda p: jax.tree.leaves(p)[0]
    want = w[0] * leaf(clients[0].params) + w[1] * leaf(clients[1].params)
    np.testing.assert_allclose(np.asarray(leaf(avg)), np.asarray(want),
                               rtol=1e-5)


def test_fedavg_rejects_heterogeneous():
    c1 = _tiny_clients(1, kind="cnn1")[0]
    c2 = _tiny_clients(1, kind="cnn2")[0]
    with pytest.raises(ValueError):
        fedavg([c1, c2])


def test_ensemble_supports_heterogeneous_models():
    """The paper's core enabler: logit averaging works across architectures
    where parameter averaging cannot."""
    c1 = _tiny_clients(1, kind="cnn1")[0]
    c2 = _tiny_clients(1, kind="cnn2")[0]
    c3 = _tiny_clients(1, kind="wrn16_1")[0]
    clients = [c1, c2, c3]
    x = jax.random.normal(jax.random.PRNGKey(0), (5, 8, 8, 1))
    specs, cparams = split_clients(clients)
    avg = ensemble_logits(specs, cparams, x)
    assert avg.shape == (5, 4)
    per = [cnn_logits(c.params, c.spec, x) for c in clients]
    want = sum(jnp.asarray(p, jnp.float32) for p in per) / 3
    np.testing.assert_allclose(np.asarray(avg), np.asarray(want), atol=1e-5)


def test_comm_ledger_one_shot_property():
    led = CommLedger()
    for i in range(5):
        led.record("up", f"client{i}", 1000, "round0-model-upload")
    assert led.rounds == 1
    assert led.uplink_bytes == 5000
    assert led.downlink_bytes == 0  # one-shot: nothing comes back


def test_param_bytes_counts_all_leaves():
    p = {"a": jnp.zeros((10,), jnp.float32), "b": jnp.zeros((4,), jnp.int32)}
    assert param_bytes(p) == 40 + 16


def test_oneshot_uplink_less_than_multiround():
    """DENSE's raison d'être: 1 round of uploads vs 2*rounds transfers."""
    p = {"w": jnp.zeros((1000,), jnp.float32)}
    m, rounds = 5, 10
    oneshot = m * param_bytes(p)
    fedavg_total = rounds * m * param_bytes(p) * 2
    assert oneshot * (2 * rounds) == fedavg_total
