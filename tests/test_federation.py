"""Grouped client-training engine equivalence + invariants.

The grouped local-update path (fl/federation.py, fl/client.py
local_update_grouped) is a pure perf refactor of the per-client python
reference loop: same seeds => same final params to float tolerance, for
LDAM margins, heterogeneous multi-group federations, and ragged shards
whose sizes don't divide batch_size. The one-shot communication profile
(m uploads, zero broadcasts) must survive grouped uploads.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_cifar import DenseExperimentConfig
from repro.core.ensemble import Client, stack_grouped
from repro.data import make_classification_data
from repro.data.pipeline import batches, build_batch_plan, pad_shards
from repro.fl import (CommLedger, build_federation, dense_multi_round,
                      fedavg, fedavg_stacked, param_bytes)
from repro.fl.client import local_update, local_update_grouped
from repro.models import layers as L
from repro.models.cnn import CNNSpec, cnn_apply, cnn_init

SCFG = DenseExperimentConfig(
    n_clients=3, alpha=0.5, local_epochs=2, batch_size=16, num_classes=4,
    image_size=8, in_ch=1, train_per_class=37, test_per_class=8,
    client_kinds=("cnn1",) * 3, global_kind="cnn1", width=0.25, nz=16,
    t_g=1, epochs=1, synth_batch=16)


def _max_diff(a, b):
    return max(float(jnp.max(jnp.abs(x - y))) for x, y in
               zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _data(seed=0, scfg=SCFG):
    return make_classification_data(
        seed, num_classes=scfg.num_classes, size=scfg.image_size,
        ch=scfg.in_ch, train_per_class=scfg.train_per_class,
        test_per_class=scfg.test_per_class)


# ------------------------------------------------------------ batch plan ---

def test_batch_plan_matches_reference_iterator():
    """Valid slots of the plan == the exact batches() index stream."""
    sizes, batch, epochs, seeds = [37, 16, 20], 8, 3, [5, 6, 7]
    plan = build_batch_plan(sizes, batch, epochs=epochs, seeds=seeds)
    assert plan.steps == epochs * plan.steps_per_epoch
    for k, (n, seed) in enumerate(zip(sizes, seeds)):
        x = np.arange(n)[:, None]
        want = [bx[:, 0] for bx, _ in
                batches(x, np.zeros(n, np.int64), batch, seed=seed,
                        epochs=epochs)]
        got = [plan.idx[k, s][plan.mask[k, s]] for s in range(plan.steps)
               if plan.mask[k, s].any()]
        assert len(want) == len(got)
        for w, g in zip(want, got):
            np.testing.assert_array_equal(w, g)
    # padding never gathers out of range
    for k, n in enumerate(sizes):
        assert plan.idx[k].max() < n


def test_pad_shards_keeps_real_rows_first():
    shards = [(np.ones((3, 2, 2, 1)), np.array([1, 2, 3])),
              (np.full((5, 2, 2, 1), 2.0), np.array([4, 5, 6, 7, 8]))]
    xs, ys = pad_shards(shards)
    assert xs.shape == (2, 5, 2, 2, 1) and ys.shape == (2, 5)
    np.testing.assert_array_equal(ys[0], [1, 2, 3, 0, 0])
    np.testing.assert_array_equal(ys[1], [4, 5, 6, 7, 8])


# ------------------------------------------------------------- masked BN ---

def test_masked_batchnorm_matches_subbatch():
    """Masked train-mode BN over a padded batch == plain BN over the
    valid sub-batch (normalized rows AND running-stat updates)."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((6, 4, 4, 3)).astype(np.float32))
    mask = jnp.asarray([True, True, True, True, False, False])
    p = L.batchnorm_init(3)
    y_m, upd_m = L.batchnorm(p, x, train=True, sample_mask=mask)
    y_r, upd_r = L.batchnorm(p, x[:4], train=True)
    np.testing.assert_allclose(np.asarray(y_m[:4]), np.asarray(y_r),
                               atol=1e-5)
    for k in ("mean", "var"):
        np.testing.assert_allclose(np.asarray(upd_m[k]),
                                   np.asarray(upd_r[k]), atol=1e-6)


@pytest.mark.parametrize("kind", ["cnn1", "wrn16_1"])
def test_masked_cnn_apply_matches_subbatch(kind):
    """cnn_apply(sample_mask) == cnn_apply on the unpadded sub-batch:
    valid logits and BN running-stat updates agree (conv-stack AND
    residual kinds)."""
    spec = CNNSpec(kind=kind, num_classes=4, in_ch=1, width=0.25,
                   image_size=8)
    params = cnn_init(jax.random.PRNGKey(0), spec)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((5, 8, 8, 1)).astype(np.float32))
    mask = jnp.asarray([True, True, True, False, False])
    lg_m, new_m, _ = cnn_apply(params, spec, x, train=True,
                               sample_mask=mask)
    lg_r, new_r, _ = cnn_apply(params, spec, x[:3], train=True)
    np.testing.assert_allclose(np.asarray(lg_m[:3]), np.asarray(lg_r),
                               atol=1e-4)
    assert _max_diff(new_m, new_r) < 1e-5


# ----------------------------------------- grouped local update ≡ python ---

@pytest.mark.parametrize("use_ldam", [False, True])
def test_grouped_local_update_matches_python(use_ldam):
    """Same seeds -> same final params, ragged shards (37, 21 with
    batch 16), LDAM margins stacked along the client axis."""
    spec = CNNSpec(kind="cnn1", num_classes=4, in_ch=1, width=0.25,
                   image_size=8)
    rng = np.random.default_rng(2)
    shards = []
    for n in (37, 21):
        x = rng.standard_normal((n, 8, 8, 1)).astype(np.float32)
        y = rng.integers(0, 4, n)
        shards.append((x, y))
    inits = [cnn_init(jax.random.PRNGKey(i), spec) for i in range(2)]
    seeds = [11, 12]

    ref = [local_update(p0, spec, x, y, epochs=2, batch_size=16,
                        use_ldam=use_ldam, num_classes=4, seed=s)[0]
           for p0, (x, y), s in zip(inits, shards, seeds)]

    xs, ys = pad_shards(shards)
    plan = build_batch_plan([37, 21], 16, epochs=2, seeds=seeds)
    stacked0 = jax.tree.map(lambda *a: jnp.stack(a), *inits)
    counts = np.stack([np.bincount(y, minlength=4) for _, y in shards])
    trained, info = local_update_grouped(
        stacked0, spec, xs, ys, plan, use_ldam=use_ldam, num_classes=4,
        class_counts=counts)
    assert info["loss"].shape == (plan.steps, 2)
    for k in range(2):
        got = jax.tree.map(lambda a, _k=k: a[_k], trained)
        assert _max_diff(got, ref[k]) < 1e-4


@pytest.mark.slow
def test_build_federation_grouped_matches_python_heterogeneous():
    """Full protocol equivalence on a 2-group federation (cnn1 x2 +
    cnn2) with Dirichlet-ragged shards; ledger records m uploads with
    per-client byte counts and zero broadcasts under both drivers."""
    scfg = dataclasses.replace(SCFG, client_kinds=("cnn1", "cnn2", "cnn1"))
    data = _data(0, scfg)
    out = {}
    for mode in ("python", "grouped"):
        led = CommLedger()
        clients, shards = build_federation(
            jax.random.PRNGKey(0),
            dataclasses.replace(scfg, client_loop_mode=mode), data,
            ledger=led)
        out[mode] = (clients, shards, led)
    cp, sp_, lp = out["python"]
    cg, sg, lg = out["grouped"]
    for a, b in zip(cp, cg):
        assert a.spec == b.spec and a.n_data == b.n_data
        np.testing.assert_array_equal(a.class_counts, b.class_counts)
        assert _max_diff(a.params, b.params) < 1e-4
    for (xa, ya), (xb, yb) in zip(sp_, sg):
        np.testing.assert_array_equal(ya, yb)
    # one-shot property under grouped uploads
    assert lg.rounds == 1 and lg.downlink_bytes == 0
    assert len([e for e in lg.events if e["dir"] == "up"]) == 3
    assert lg.uplink_bytes == lp.uplink_bytes \
        == sum(param_bytes(c.params) for c in cg)
    # engine's stacked params ARE the ensemble representation (no restack)
    gspecs, gparams = stack_grouped(cg)
    assert gspecs == cg.grouped[0]
    assert all(ga is gb for ga, gb in zip(gparams, cg.grouped[1]))
    assert [(s.kind, n) for s, n in gspecs] == [("cnn1", 2), ("cnn2", 1)]


@pytest.mark.slow
def test_multiround_grouped_matches_python():
    """Round-r warm starts and per-round seeds survive the grouped
    rewrite: identical global model after 2 rounds."""
    scfg = dataclasses.replace(SCFG, n_clients=2,
                               client_kinds=("cnn1", "cnn1"))
    data = _data(5, scfg)
    out = {}
    for mode in ("python", "grouped"):
        gp, spec, _ = dense_multi_round(
            jax.random.PRNGKey(6),
            dataclasses.replace(scfg, client_loop_mode=mode), data,
            rounds=2)
        out[mode] = gp
    assert _max_diff(out["python"], out["grouped"]) < 5e-3


def test_unknown_client_loop_mode_raises():
    scfg = dataclasses.replace(SCFG, client_loop_mode="nope")
    data = _data(0)
    with pytest.raises(ValueError):
        build_federation(jax.random.PRNGKey(0), scfg, data)
    with pytest.raises(ValueError):
        dense_multi_round(jax.random.PRNGKey(0), scfg, data, rounds=1)


# ---------------------------------------------------------------- fedavg ---

def _tiny_clients(n=2, n_data=(10, 20)):
    spec = CNNSpec(kind="cnn1", num_classes=4, in_ch=1, width=0.25,
                   image_size=8)
    return [Client(spec=spec, params=cnn_init(jax.random.PRNGKey(i), spec),
                   n_data=nd) for i, nd in zip(range(n), n_data)]


def test_fedavg_rejects_nonpositive_n_data():
    with pytest.raises(ValueError):
        fedavg(_tiny_clients(2, (10, 0)))
    with pytest.raises(ValueError):
        fedavg(_tiny_clients(2, (-3, 5)))
    stacked = jax.tree.map(lambda *a: jnp.stack(a),
                           *[c.params for c in _tiny_clients()])
    with pytest.raises(ValueError):
        fedavg_stacked(stacked, [0, 7])


def test_fedavg_stacked_matches_listwise():
    clients = _tiny_clients()
    stacked = jax.tree.map(lambda *a: jnp.stack(a),
                           *[c.params for c in clients])
    got = fedavg_stacked(stacked, [c.n_data for c in clients])
    want = fedavg(clients)
    assert _max_diff(got, want) < 1e-6
