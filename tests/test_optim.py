"""Optimizer / schedule / LDAM unit + property tests."""
import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro import optim

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


def test_sgd_momentum_matches_torch_semantics():
    """buf = mu*buf + g; p -= lr*buf (two manual steps)."""
    opt = optim.sgd(0.1, momentum=0.9)
    p = {"w": jnp.array([1.0])}
    s = opt.init(p)
    g = {"w": jnp.array([1.0])}
    p, s = opt.update(g, s, p)       # buf=1,   p=1-0.1
    p, s = opt.update(g, s, p)       # buf=1.9, p=0.9-0.19
    np.testing.assert_allclose(float(p["w"][0]), 1 - 0.1 - 0.19, rtol=1e-6)


def test_adam_first_step_size():
    """With bias correction, |step_1| ~= lr regardless of grad scale."""
    for scale in (1e-3, 1.0, 1e3):
        opt = optim.adam(0.01)
        p = {"w": jnp.array([0.0])}
        s = opt.init(p)
        p2, _ = opt.update({"w": jnp.array([scale])}, s, p)
        np.testing.assert_allclose(abs(float(p2["w"][0])), 0.01, rtol=1e-3)


@given(st.floats(0.1, 10.0))
def test_clip_by_global_norm(max_norm):
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((2,), -4.0)}
    clipped, norm = optim.clip_by_global_norm(g, max_norm)
    new_norm = float(optim.global_norm(clipped))
    assert new_norm <= max_norm * 1.001
    if float(norm) <= max_norm:      # small grads untouched
        np.testing.assert_allclose(np.asarray(clipped["a"]),
                                   np.asarray(g["a"]), rtol=1e-6)


def test_schedules():
    c = optim.constant(0.1)
    assert c(0) == c(1000) == 0.1
    cos = optim.cosine(1.0, 100)
    assert float(cos(0)) == 1.0
    assert float(cos(100)) < 1e-6
    np.testing.assert_allclose(float(cos(50)), 0.5, rtol=1e-5)
    wc = optim.warmup_cosine(1.0, 10, 110)
    assert float(wc(0)) == 0.0
    np.testing.assert_allclose(float(wc(10)), 1.0, atol=1e-6)


def test_ldam_margins_order():
    """Rarer classes get larger margins (the LDAM idea)."""
    counts = jnp.array([1000.0, 100.0, 10.0])
    m = optim.class_margins(counts)
    assert float(m[2]) > float(m[1]) > float(m[0])
    assert float(jnp.max(m)) == np.float32(0.5)


def test_ldam_loss_exceeds_ce_for_rare_true_class():
    logits = jnp.array([[2.0, 0.0, 0.0]])
    y = jnp.array([2])                        # rare class
    margins = optim.class_margins(jnp.array([1000.0, 100.0, 1.0]))
    ldam = float(optim.ldam_loss(logits, y, margins, s=1.0))
    logp = jax.nn.log_softmax(logits, -1)
    ce = float(-logp[0, 2])
    assert ldam > ce                         # margin makes it harder
