"""Per-architecture smoke tests (reduced same-family configs) + decode
consistency + a short training-convergence check."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import available_archs, get_config, get_smoke_config
from repro.models import transformer as T

ARCHS = available_archs()
KEY = jax.random.PRNGKey(0)


def _inputs(cfg, B, S):
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    vision = (jax.random.normal(KEY, (B, cfg.n_patches, cfg.vision_dim))
              if cfg.family == "vlm" else None)
    return tokens, vision


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes_no_nans(arch):
    cfg = get_smoke_config(arch)
    assert cfg.n_layers <= 6 and cfg.d_model <= 512
    assert cfg.n_experts <= 4
    params = T.init_model(KEY, cfg)
    B, S = 2, 32
    tokens, vision = _inputs(cfg, B, S)
    logits, cache, aux = T.forward(params, cfg, tokens=tokens, vision=vision)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))
    if cfg.family == "moe":
        assert float(aux["moe_aux"]) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step_no_nans(arch):
    """One optimizer step on the reduced config (assignment requirement)."""
    from repro.launch import steps as ST
    cfg = get_smoke_config(arch).replace(capacity_factor=4.0)
    B, S = 2, 32
    state = ST.make_train_state(KEY, cfg, lr=1e-3)
    step = jax.jit(ST.make_train_step(cfg, None, lr=1e-3))
    tokens, vision = _inputs(cfg, B, S + 1)
    batch = {"tokens": tokens[:, :S], "labels": tokens[:, 1:]}
    if vision is not None:
        batch["vision"] = vision
    new_state, m = step(state, batch)
    assert np.isfinite(float(m["loss"]))
    assert float(m["grad_norm"]) > 0
    # params actually changed
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                     state["params"], new_state["params"])
    assert max(jax.tree.leaves(d)) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_full_forward(arch):
    cfg = get_smoke_config(arch).replace(capacity_factor=64.0)
    params = T.init_model(KEY, cfg)
    B, S = 2, 16
    tokens, vision = _inputs(cfg, B, S + 1)
    full, _, _ = T.forward(params, cfg, tokens=tokens, vision=vision)
    cache = T.init_cache(cfg, B, S + 1)
    pos = jnp.arange(S, dtype=jnp.int32)
    _, cache, _ = T.forward(params, cfg, tokens=tokens[:, :S], positions=pos,
                            cache=cache, cache_pos=jnp.int32(0),
                            vision=vision)
    one, cache, _ = T.forward(params, cfg, tokens=tokens[:, S:S + 1],
                              positions=jnp.array([S], jnp.int32),
                              cache=cache, cache_pos=jnp.int32(S),
                              vision=vision, decode=True)
    np.testing.assert_allclose(np.asarray(full[:, -1]),
                               np.asarray(one[:, 0]), atol=5e-3)


def test_full_configs_match_assignment():
    expect = {
        "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "deepseek-v2-236b": (60, 5120, 128, 128, 12288, 102400),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 11264, 102400),
        "qwen1-5-4b": (40, 2560, 20, 20, 6912, 151936),
        "phi3-medium-14b": (40, 5120, 40, 10, 17920, 100352),
        "llama3-2-3b": (28, 3072, 24, 8, 8192, 128256),
        "llama3-2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
        "mamba2-130m": (24, 768, 0, 0, 0, 50280),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
    }
    for arch, (L, d, h, kv, ff, V) in expect.items():
        cfg = get_config(arch)
        assert cfg.n_layers == L, arch
        assert cfg.d_model == d, arch
        assert cfg.n_heads == h, arch
        assert cfg.n_kv_heads == kv, arch
        assert cfg.d_ff == ff, arch
        assert cfg.vocab_size == V, arch
    assert get_config("deepseek-v2-236b").n_experts == 160
    assert get_config("deepseek-v2-236b").top_k == 6
    assert get_config("deepseek-v2-lite-16b").n_experts == 64
    assert get_config("mamba2-130m").ssm_state == 128
    assert get_config("zamba2-7b").ssm_state == 64
    assert get_config("gemma3-4b").sliding_window > 0


def test_param_count_sane():
    # analytic count should be within 2x of the nameplate for dense archs
    approx = {"llama3-2-3b": 3e9, "qwen1-5-4b": 4e9, "phi3-medium-14b": 14e9,
              "gemma3-4b": 4e9, "zamba2-7b": 7e9, "mamba2-130m": 130e6}
    for arch, n in approx.items():
        got = get_config(arch).param_count()
        assert 0.4 * n < got < 2.5 * n, (arch, got, n)
    # deepseek-v2 236B total / ~21B active
    ds = get_config("deepseek-v2-236b")
    assert 150e9 < ds.param_count() < 320e9
    assert 10e9 < ds.active_param_count() < 40e9


def test_gemma3_window_pattern():
    from repro.models.transformer import layer_windows
    w = layer_windows(get_config("gemma3-4b"))
    assert len(w) == 34
    assert (w == 0).sum() == 34 // 6          # every 6th layer global
    assert set(w[w != 0]) == {1024}


def test_lm_training_reduces_loss():
    """End-to-end: a reduced llama on the Markov stream must learn."""
    from repro.launch.train import train
    _, losses = train("llama3.2-3b", steps=30, batch=8, seq=64, smoke=True,
                      lr=3e-3, log_every=1000)
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])
