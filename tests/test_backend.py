"""Backend execution-policy registry tests (configs/backend.py,
DESIGN.md §11): detection precedence, per-scfg knob precedence, the
legacy-kwarg deprecation shim, autotune-cache behavior (hits skip
timing; corruption degrades with a warning; tie-breaking is
deterministic), bit-stable resolution, and the AST enforcement sweep
that keeps configs/backend.py the ONLY module deciding modes/blocks."""
import ast
import json
import os
import warnings
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import pytest

from repro.configs import backend as B
from repro.kernels import ops

SRC = os.path.join(os.path.dirname(__file__), "..", "src", "repro")


@pytest.fixture(autouse=True)
def _isolated_registry(monkeypatch, tmp_path):
    """Every test gets a private writable cache and clean memos; the
    committed seed cache stays visible (it is part of the contract)."""
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE",
                       str(tmp_path / "autotune.json"))
    monkeypatch.delenv("REPRO_BACKEND", raising=False)
    monkeypatch.delenv("REPRO_INTERPRET", raising=False)
    monkeypatch.delenv("REPRO_AUTOTUNE", raising=False)
    B.clear_caches()
    yield
    B.clear_caches()


# ------------------------------------------------------------- detection

def test_backend_env_override(monkeypatch):
    assert B.detect_backend(None) == jax.default_backend()
    monkeypatch.setenv("REPRO_BACKEND", "gpu")
    assert B.detect_backend(None) == "gpu"
    pol = B.resolve_exec_policy(None)
    assert (pol.backend, pol.loop, pol.distill_kl, pol.kernel_vjp) == \
        ("gpu", "fused", "fused", "fused")
    # scfg.backend beats the env var
    assert B.detect_backend(SimpleNamespace(backend="tpu")) == "tpu"
    with pytest.raises(ValueError, match="unknown backend"):
        B.detect_backend(SimpleNamespace(backend="mps"))


def test_gpu_profile_not_interpret(monkeypatch):
    """The _auto_interpret bugfix: gpu must NOT silently run interpret
    mode (only cpu defaults to interpret=True), and REPRO_INTERPRET
    overrides the registry in both directions."""
    assert B.resolve_exec_policy(None, backend="cpu").interpret is True
    assert B.resolve_exec_policy(None, backend="gpu").interpret is False
    assert B.resolve_exec_policy(None, backend="tpu").interpret is False
    monkeypatch.setenv("REPRO_INTERPRET", "1")
    assert B.resolve_exec_policy(None, backend="gpu").interpret is True
    monkeypatch.setenv("REPRO_INTERPRET", "0")
    assert B.resolve_exec_policy(None, backend="cpu").interpret is False


# ------------------------------------------------------------ precedence

def test_scfg_knobs_beat_registry():
    scfg = SimpleNamespace(loop_mode="fused", distill_kl_mode="fused",
                           ensemble_shard_mode="clients")
    pol = B.resolve_exec_policy(scfg, backend="cpu")
    assert pol.loop == "fused"
    assert pol.distill_kl == "fused"
    assert pol.ensemble_shard == "clients"
    # unset knobs fall through to the cpu profile
    assert pol.client_loop == "grouped"
    assert pol.kernel_vjp == "ref"


def test_resolution_validates_modes():
    with pytest.raises(ValueError, match="unknown loop_mode"):
        B.resolve_exec_policy(SimpleNamespace(loop_mode="vectorized"))
    with pytest.raises(ValueError, match="unknown client_loop_mode"):
        B.resolve_exec_policy(SimpleNamespace(client_loop_mode="batched"))
    with pytest.raises(ValueError, match="unknown ensemble_shard_mode"):
        B.resolve_exec_policy(SimpleNamespace(ensemble_shard_mode="data"))
    with pytest.raises(ValueError, match="unknown distill_kl mode"):
        B.resolve_exec_policy(SimpleNamespace(distill_kl_mode="pallas"))
    with pytest.raises(ValueError, match="unknown kernel_vjp mode"):
        B.resolve_exec_policy(SimpleNamespace(kernel_vjp_mode="nope"))


def test_kernel_blocks_override_precedence():
    scfg = SimpleNamespace(kernel_blocks=(("distill_kl", (128, 1024)),))
    pol = B.resolve_exec_policy(scfg, backend="cpu")
    assert pol.blocks_for("distill_kl") == (128, 1024)
    # other kernels keep the registry table
    assert pol.blocks_for("flash_attention") == (128, 128)
    # mapping form with named values, None inherits per position
    scfg2 = SimpleNamespace(
        kernel_blocks={"flash_attention": {"block_q": 64}})
    pol2 = B.resolve_exec_policy(scfg2, backend="cpu")
    assert pol2.blocks_for("flash_attention") == (64, 128)
    with pytest.raises(ValueError, match="unknown kernel"):
        B.resolve_exec_policy(
            SimpleNamespace(kernel_blocks={"matmul": (8,)}))


def test_override_blocks_method():
    pol = B.resolve_exec_policy(None, backend="cpu")
    pol2 = pol.override_blocks("ssd_scan", chunk=32)
    assert pol2.blocks_for("ssd_scan") == (32,)
    assert pol.blocks_for("ssd_scan") == (128,)     # frozen original
    with pytest.raises(ValueError, match="unknown block args"):
        pol.override_blocks("ssd_scan", block_q=8)


def test_resolution_bit_stable():
    scfg = SimpleNamespace(loop_mode="fused")
    a = B.resolve_exec_policy(scfg, backend="cpu")
    b = B.resolve_exec_policy(scfg, backend="cpu")
    assert a == b and hash(a) == hash(b)
    # idempotent: resolving a policy returns it unchanged
    assert B.resolve_exec_policy(a) is a


# -------------------------------------------------------- legacy shim

def test_flash_shim_equivalent_to_policy():
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 2, 16, 8))
    pol = B.resolve_exec_policy(None, backend="cpu").replace(
        kernel_vjp="autodiff").override_blocks(
            "flash_attention", block_q=8, block_k=8)
    with pytest.warns(DeprecationWarning, match="flash_attention"):
        old = ops.flash_attention(q, q, q, block_q=8, block_k=8,
                                  interpret=True)
    new = ops.flash_attention(q, q, q, policy=pol)
    assert jnp.allclose(old, new, atol=1e-6)


def test_ssd_shim_equivalent_to_policy():
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (1, 32, 2, 4))
    dt = jnp.full((1, 32, 2), 0.1)
    a = -jnp.ones((2,))
    bm = jax.random.normal(jax.random.PRNGKey(2), (1, 32, 2, 4))
    pol = B.resolve_exec_policy(None, backend="cpu").replace(
        kernel_vjp="autodiff").override_blocks("ssd_scan", chunk=16)
    with pytest.warns(DeprecationWarning, match="ssd_scan"):
        old_y, old_s = ops.ssd_scan(x, dt, a, bm, bm, chunk=16,
                                    interpret=True)
    new_y, new_s = ops.ssd_scan(x, dt, a, bm, bm, policy=pol)
    assert jnp.allclose(old_y, new_y, atol=1e-6)
    assert jnp.allclose(old_s, new_s, atol=1e-6)


def test_distill_kl_shim_equivalent_to_policy():
    t = jax.random.normal(jax.random.PRNGKey(3), (8, 64))
    s = jax.random.normal(jax.random.PRNGKey(4), (8, 64))
    pol = B.resolve_exec_policy(None, backend="cpu").override_blocks(
        "distill_kl", block_rows=4, block_v=32)
    with pytest.warns(DeprecationWarning, match="distill_kl"):
        old = ops.distill_kl(t, s, 4, 32)
    new = ops.distill_kl(t, s, policy=pol)
    assert jnp.allclose(old, new, atol=1e-6)


def test_policy_path_emits_no_warning():
    t = jnp.zeros((4, 32))
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        ops.distill_kl(t, t)
        ops.flash_attention(jnp.zeros((1, 1, 8, 4)), jnp.zeros((1, 1, 8, 4)),
                            jnp.zeros((1, 1, 8, 4)))


# ---------------------------------------------------------- autotuner

def _write_cache(path, entries):
    with open(path, "w") as f:
        json.dump({"version": 1, "entries": entries}, f)
    B.clear_caches()


def test_cache_hit_skips_timing(monkeypatch, tmp_path):
    path = tmp_path / "autotune.json"
    _write_cache(path, {"cpu/distill_kl/64x128":
                        {"blocks": {"block_rows": 32, "block_v": 64},
                         "us": 1.0}})
    monkeypatch.setenv("REPRO_AUTOTUNE", "1")

    def boom(fn, reps=3):
        raise AssertionError("timer ran on a cache hit")

    monkeypatch.setattr(B, "_timer", boom)
    pol = B.resolve_exec_policy(None, backend="cpu")
    assert B.autotune_blocks("distill_kl", (40, 100), pol) == (32, 64)
    # the resolved policy carries the tuned entry for blocks_for too
    assert pol.blocks_for("distill_kl", (40, 100)) == (32, 64)
    assert pol.blocks_for("distill_kl", (40, 4000)) == (256, 2048)


def test_autotune_disabled_returns_registry(monkeypatch):
    monkeypatch.setattr(B, "_timer",
                        lambda fn, reps=3: pytest.fail("timed while off"))
    pol = B.resolve_exec_policy(None, backend="cpu")
    # bucket 64x64 is deliberately absent from the committed seed cache
    assert B.autotune_blocks("flash_attention", (33, 33), pol) == \
        pol.blocks_for("flash_attention")
    assert not os.path.exists(B._default_cache_path())


def test_corrupt_cache_warns_and_falls_back(tmp_path):
    path = tmp_path / "autotune.json"
    path.write_text("{not json")
    B.clear_caches()
    with pytest.warns(UserWarning, match="unreadable autotune cache"):
        pol = B.resolve_exec_policy(None, backend="cpu")
    assert pol.blocks_for("distill_kl") == (256, 2048)


def test_stale_cache_version_warns_and_falls_back(tmp_path):
    path = tmp_path / "autotune.json"
    path.write_text(json.dumps({"version": 99, "entries": {}}))
    B.clear_caches()
    with pytest.warns(UserWarning, match="unreadable autotune cache"):
        pol = B.resolve_exec_policy(None, backend="cpu")
    assert pol.blocks_for("ssd_scan") == (128,)


def test_deterministic_winner_under_ties(monkeypatch, tmp_path):
    """All candidates time identically → the EARLIEST candidate in
    canonical _CANDIDATES order wins, every run."""
    monkeypatch.setenv("REPRO_AUTOTUNE", "1")
    monkeypatch.setattr(B, "_timer", lambda fn, reps=3: 100.0)
    monkeypatch.setattr(B, "_candidate_runner",
                        lambda *a, **k: (lambda: None))
    pol = B.resolve_exec_policy(None, backend="cpu")
    won = B.autotune_blocks("distill_kl", (1000, 4000), pol)
    assert won == B._CANDIDATES["distill_kl"][0] == (256, 2048)
    # persisted: a second resolution sees it as a cache hit
    doc = json.loads(open(B._default_cache_path()).read())
    assert doc["entries"]["cpu/distill_kl/1024x4096"]["blocks"] == \
        {"block_rows": 256, "block_v": 2048}
    monkeypatch.setattr(B, "_timer",
                        lambda fn, reps=3: pytest.fail("re-timed a hit"))
    assert B.autotune_blocks("distill_kl", (1000, 4000), pol) == won


def test_candidates_clamped_and_deduped(monkeypatch):
    """Tiny problems clamp every candidate to the same shape — exactly
    one timing run, winner equals the clamped shape."""
    monkeypatch.setenv("REPRO_AUTOTUNE", "1")
    calls = []

    def fake_timer(fn, reps=3):
        calls.append(1)
        return 5.0

    monkeypatch.setattr(B, "_timer", fake_timer)
    monkeypatch.setattr(B, "_candidate_runner",
                        lambda *a, **k: (lambda: None))
    pol = B.resolve_exec_policy(None, backend="cpu")
    assert B.autotune_blocks("ssd_scan", (16,), pol) == (16,)
    assert len(calls) == 1


def test_shape_bucket():
    assert B.shape_bucket("distill_kl", (40, 100)) == "64x128"
    assert B.shape_bucket("flash_attention", (128, 128)) == "128x128"
    assert B.shape_bucket("ssd_scan", (1,)) == "1"


def test_seed_cache_is_valid():
    """The committed seed cache must parse cleanly (no warning) and only
    contain known backends/kernels with well-formed block values."""
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        entries = B._read_cache_file(B._SEED_CACHE)
    assert entries, "seed cache missing or empty"
    for (backend, kernel, bucket), vals in entries.items():
        assert backend in B.BACKENDS
        assert len(vals) == len(B.KERNEL_BLOCK_ARGS[kernel])
        assert all(isinstance(v, int) and v > 0 for v in vals)


# ------------------------------------------------- federation-scale knobs

def test_scale_knobs_resolve_and_validate():
    """The DESIGN.md §13 knobs route through the registry like every
    other mode: scfg beats profile, defaults keep every knob off
    (= bit-compatible m=10 path), unknown values fail loudly."""
    scfg = SimpleNamespace(plan_bucketing="pow2", stack_chunk=16,
                           fedavg_mode="tree", fedavg_branch=4,
                           teacher_chunk=8)
    pol = B.resolve_exec_policy(scfg, backend="cpu")
    assert (pol.bucketing, pol.stack_chunk, pol.fedavg,
            pol.fedavg_branch, pol.teacher_chunk) == \
        ("pow2", 16, "tree", 4, 8)
    for bk in B.BACKENDS:
        d = B.resolve_exec_policy(None, backend=bk)
        assert (d.bucketing, d.stack_chunk, d.fedavg, d.teacher_chunk) \
            == ("off", 0, "flat", 0)
    with pytest.raises(ValueError, match="unknown plan_bucketing"):
        B.resolve_exec_policy(SimpleNamespace(plan_bucketing="bins"))
    with pytest.raises(ValueError, match="unknown fedavg_mode"):
        B.resolve_exec_policy(SimpleNamespace(fedavg_mode="ring"))


# --------------------------------------------- backward-kernel autotune

def test_bwd_kernel_entries_resolve():
    """``{kernel}_bwd`` is a first-class registry row: its own defaults,
    candidates and overrides, never aliased to the forward entry."""
    pol = B.resolve_exec_policy(None, backend="cpu")
    assert pol.blocks_for("distill_kl_bwd") == (256, 2048)
    assert pol.blocks_for("flash_attention_bwd") == (128, 128)
    assert "ssd_scan_bwd" not in B.KERNEL_BLOCK_ARGS   # documented exception
    scfg = SimpleNamespace(
        kernel_blocks={"distill_kl_bwd": {"block_rows": 64}})
    pol2 = B.resolve_exec_policy(scfg, backend="cpu")
    assert pol2.blocks_for("distill_kl_bwd") == (64, 2048)
    assert pol2.blocks_for("distill_kl") == (256, 2048)  # fwd untouched


def test_bwd_override_skips_autotune(monkeypatch):
    """ops._bwd_blocks precedence: an explicit _bwd override wins even
    with REPRO_AUTOTUNE=1 — no timing run may fire (timer raises)."""
    monkeypatch.setenv("REPRO_AUTOTUNE", "1")
    monkeypatch.setattr(B, "_timer", lambda *a, **k: (_ for _ in ()).throw(
        AssertionError("timed despite override")))
    scfg = SimpleNamespace(
        kernel_blocks={"flash_attention_bwd": (64, 64)})
    pol = B.resolve_exec_policy(scfg, backend="cpu")
    assert ops._bwd_blocks("flash_attention", pol, (128, 128)) == (64, 64)


def test_bwd_autotune_disabled_returns_registry():
    pol = B.resolve_exec_policy(None, backend="cpu")
    assert ops._bwd_blocks("distill_kl", pol, (999, 999)) == \
        pol.blocks_for("distill_kl_bwd", (999, 999))


def test_seed_cache_covers_bwd_kernels():
    """The committed seed cache pins backward winners too, so CI never
    times (or silently falls back) on the tuned-backward path."""
    entries = B._read_cache_file(B._SEED_CACHE)
    kernels = {k for (_, k, _) in entries}
    assert {"distill_kl_bwd", "flash_attention_bwd"} <= kernels


# ------------------------------------------------- AST enforcement sweep

_BANNED_ATTRS = {"loop_mode", "client_loop_mode", "ensemble_shard_mode",
                 "distill_kl_mode", "kernel_vjp_mode", "plan_bucketing",
                 "fedavg_mode"}
_BLOCK_NAMES = {"block_q", "block_k", "block_rows", "block_v", "chunk",
                "page"}


def _src_files():
    for root, dirs, files in os.walk(SRC):
        if os.path.basename(root) == "configs":
            dirs[:] = []
            continue
        dirs[:] = [d for d in dirs if d != "__pycache__"]
        for f in sorted(files):
            if f.endswith(".py"):
                yield os.path.join(root, f)


def test_no_raw_knob_reads_outside_configs():
    """Outside configs/, no module may read the mode knobs off a config
    (attribute access or getattr-by-string) — resolve_exec_policy is the
    only resolution point. Docstrings/comments are naturally exempt."""
    bad = []
    for path in _src_files():
        tree = ast.parse(open(path).read(), filename=path)
        for node in ast.walk(tree):
            if isinstance(node, ast.Attribute) and \
                    node.attr in _BANNED_ATTRS:
                bad.append(f"{path}:{node.lineno} .{node.attr}")
            if isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Name) and \
                    node.func.id == "getattr" and len(node.args) >= 2 and \
                    isinstance(node.args[1], ast.Constant) and \
                    node.args[1].value in _BANNED_ATTRS:
                bad.append(f"{path}:{node.lineno} "
                           f"getattr(..., {node.args[1].value!r})")
    assert not bad, "raw mode-knob reads outside configs/:\n" + \
        "\n".join(bad)


def test_no_hardcoded_block_shapes_outside_configs():
    """Outside configs/, no call may pass a literal int for a kernel
    block argument and no function may default one to a literal int —
    block shapes come from the registry/autotuner via ExecPolicy."""
    bad = []
    for path in _src_files():
        tree = ast.parse(open(path).read(), filename=path)
        for node in ast.walk(tree):
            if isinstance(node, ast.Call):
                for kw in node.keywords:
                    if kw.arg in _BLOCK_NAMES and \
                            isinstance(kw.value, ast.Constant) and \
                            isinstance(kw.value.value, int):
                        bad.append(f"{path}:{node.lineno} "
                                   f"{kw.arg}={kw.value.value}")
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                a = node.args
                pos = a.posonlyargs + a.args
                for arg, dflt in zip(pos[len(pos) - len(a.defaults):],
                                     a.defaults):
                    if arg.arg in _BLOCK_NAMES and \
                            isinstance(dflt, ast.Constant) and \
                            isinstance(dflt.value, int):
                        bad.append(f"{path}:{node.lineno} def "
                                   f"{node.name}({arg.arg}={dflt.value})")
                for arg, dflt in zip(a.kwonlyargs, a.kw_defaults):
                    if dflt is not None and arg.arg in _BLOCK_NAMES and \
                            isinstance(dflt, ast.Constant) and \
                            isinstance(dflt.value, int):
                        bad.append(f"{path}:{node.lineno} def "
                                   f"{node.name}({arg.arg}={dflt.value})")
    assert not bad, "hardcoded block shapes outside configs/:\n" + \
        "\n".join(bad)
