"""Equivalence tests for the §Perf optimizations (EXPERIMENTS.md):
blockwise attention, chunked distillation KL, decode-cache sharding rules.
Optimizations must never change the math."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.attention as A
from repro.configs.base import get_smoke_config
from repro.models import transformer as T


@pytest.fixture(autouse=True)
def small_blocks(monkeypatch):
    monkeypatch.setattr(A, "BLOCKWISE_MIN", 32)
    yield


@pytest.mark.parametrize("arch", ["llama3.2-3b", "gemma3-4b",
                                  "deepseek-v2-lite-16b"])
def test_blockwise_attention_matches_materialized(arch):
    cfg = get_smoke_config(arch).replace(capacity_factor=64.0,
                                         attn_block_q=16, attn_block_kv=16)
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0,
                                cfg.vocab_size)
    a, _, _ = T.forward(params, cfg, tokens=tokens)
    b, _, _ = T.forward(params, cfg.replace(use_blockwise_attn=False),
                        tokens=tokens)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4)


def test_blockwise_direct_vs_sdpa_with_window():
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 64, 2, 2, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 2, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 64, 2, 16))
    pos = jnp.arange(64)
    for win in (0, 24):
        out = A._sdpa_blockwise(q, k, v, pos, pos, win, 0.25, bq=16, bk=16)
        mask = (pos[None, :] <= pos[:, None]) \
            & ((pos[:, None] - pos[None, :] < win) | (win == 0))
        want = A._sdpa(q, k, v, mask, 0.25)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=1e-5)


def test_chunked_kl_matches_materialized():
    from repro.core import dense_llm as DL
    from repro.launch.mesh import make_host_mesh
    from repro import optim
    cfg = get_smoke_config("llama3.2-3b")
    mesh = make_host_mesh(1)
    stacked = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[T.init_model(jax.random.PRNGKey(i), cfg) for i in range(2)])
    stu = T.init_model(jax.random.PRNGKey(9), cfg)
    opt = optim.adam(1e-4)
    state = {"params": stu, "opt": opt.init(stu),
             "step": jnp.zeros((), jnp.int32)}
    emb = jax.random.normal(jax.random.PRNGKey(3), (2, 64, cfg.d_model))
    with mesh:
        s1 = DL.make_pod_distill_step(cfg, mesh, n_clients=2,
                                      chunked_kl=False)
        s2 = DL.make_pod_distill_step(cfg, mesh, n_clients=2,
                                      chunked_kl=True, kl_chunk=16)
        st1, m1 = jax.jit(s1)(state, stacked, emb)
        st2, m2 = jax.jit(s2)(state, stacked, emb)
    np.testing.assert_allclose(float(m1["dis_loss"]), float(m2["dis_loss"]),
                               rtol=1e-5)
    # resulting parameter updates identical too
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))),
        st1["params"], st2["params"])
    assert max(jax.tree.leaves(d)) < 1e-4


def test_cache_seq_sharding_rule():
    """§Perf-3: replicated-attention archs shard the cache S dim over
    model; sharded-attention archs keep head sharding."""
    from types import SimpleNamespace
    from jax.sharding import PartitionSpec as P
    from repro.configs.base import get_config
    from repro.launch import shardings as SH
    from repro.launch import specs as SP
    mesh = SimpleNamespace(axis_names=("data", "model"),
                           shape={"data": 16, "model": 16})

    def kv_spec(arch, shape):
        cfg = get_config(arch)
        spec = SP.input_specs(cfg, shape)
        cs = SH.cache_specs(cfg, spec["cache"], mesh,
                            batch=SP.SHAPES[shape]["batch"])
        leaves = jax.tree_util.tree_leaves(
            cs, is_leaf=lambda x: isinstance(x, P))
        return leaves[0]

    qwen = kv_spec("qwen1.5-4b", "decode_32k")        # replicated attn
    assert "model" in jax.tree_util.tree_leaves(tuple(qwen)) or \
        any(a == "model" or (isinstance(a, tuple) and "model" in a)
            for a in qwen)
    music = kv_spec("musicgen-large", "decode_32k")   # head-sharded attn
    # heads dim (index -2 of the unstacked (B,S,kh,hd)) carries model
    assert music[-2] == "model"
