"""Data pipeline + checkpoint tests."""
import jax
import numpy as np
import jax.numpy as jnp
from _hyp import given, settings, st

from repro.data import (batches, lm_batches, make_classification_data,
                        make_lm_data)
from repro.checkpoint import save_checkpoint, restore_checkpoint

settings.register_profile("ci", max_examples=15, deadline=None)
settings.load_profile("ci")


def test_classification_data_shapes_and_range():
    d = make_classification_data(0, num_classes=4, size=16, ch=3,
                                 train_per_class=20, test_per_class=5)
    x, y = d["train"]
    assert x.shape == (80, 16, 16, 3) and y.shape == (80,)
    assert x.min() >= -1.0 and x.max() <= 1.0
    assert set(y.tolist()) == set(range(4))
    xt, yt = d["test"]
    assert xt.shape == (20, 16, 16, 3)


def test_classification_data_is_learnable_structure():
    """Same-class samples must be closer than cross-class (signal exists)."""
    d = make_classification_data(1, num_classes=4, size=16, ch=1,
                                 train_per_class=30, test_per_class=5)
    x, y = d["train"]
    mus = np.stack([x[y == c].mean(0).ravel() for c in range(4)])
    within = np.mean([np.linalg.norm(x[y == c] - mus[c].reshape(1, 16, 16, 1))
                      for c in range(4)])
    cross = np.mean([np.linalg.norm(mus[a] - mus[b])
                     for a in range(4) for b in range(4) if a != b])
    assert cross > 0.5  # class templates are distinct


def test_deterministic_given_seed():
    a = make_classification_data(7, num_classes=2, size=8, ch=1,
                                 train_per_class=4, test_per_class=2)
    b = make_classification_data(7, num_classes=2, size=8, ch=1,
                                 train_per_class=4, test_per_class=2)
    np.testing.assert_array_equal(a["train"][0], b["train"][0])


@given(st.integers(1, 5), st.integers(8, 32))
def test_batches_cover_dataset_every_epoch(epochs, bs):
    x = np.arange(100, dtype=np.float32)[:, None]
    y = np.arange(100, dtype=np.int32)
    seen = []
    for bx, by in batches(x, y, bs, seed=0, epochs=epochs):
        assert len(bx) == len(by) <= bs
        seen.extend(by.tolist())
    assert len(seen) == 100 * epochs
    assert np.bincount(np.array(seen) % 100).min() == epochs


def test_lm_data_and_batches():
    toks = make_lm_data(0, vocab=64, n_tokens=5000)
    assert toks.min() >= 0 and toks.max() < 64
    for x, y in lm_batches(toks, batch=4, seq=16, seed=0, steps=3):
        assert x.shape == (4, 16) and y.shape == (4, 16)
        np.testing.assert_array_equal(x[:, 1:], y[:, :-1])  # shifted


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": {"b": jnp.arange(6).reshape(2, 3).astype(jnp.float32)},
            "c": [jnp.ones((4,)), jnp.zeros((2, 2))]}
    p = str(tmp_path / "ck")
    save_checkpoint(p, tree, meta={"step": 3})
    back = restore_checkpoint(p, tree)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
