"""scfg.distill_kl_mode routing equivalence: "fused" (the Pallas
custom-VJP kernel pair, DESIGN.md §9) must reproduce "ref" (materialized
jnp autodiff) through every layer that consumes it — the loss functions,
the CNN-scale DENSE server steps (core/dense), and the pod-sharded LLM
student step (core/dense_llm via launch/steps)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import losses as LS

KEY = jax.random.PRNGKey(0)


# ------------------------------------------------------------- losses --

def test_softmax_kl_fused_matches_ref_with_temperature():
    ks = jax.random.split(KEY, 2)
    p = jax.random.normal(ks[0], (12, 200)) * 3
    q = jax.random.normal(ks[1], (12, 200)) * 3
    for temp in (1.0, 2.5):
        a = LS.softmax_kl(p, q, temp)
        b = LS.softmax_kl(p, q, temp, mode="fused", block_rows=4, block_v=64)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
        # gradients through BOTH logit tensors (incl. the 1/T chain rule)
        ga = jax.grad(lambda *x: jnp.mean(LS.softmax_kl(*x, temp)),
                      argnums=(0, 1))(p, q)
        gb = jax.grad(lambda *x: jnp.mean(LS.softmax_kl(
            *x, temp, mode="fused", block_rows=4, block_v=64)),
            argnums=(0, 1))(p, q)
        for x, y in zip(ga, gb):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       atol=1e-6)


def test_softmax_kl_fused_accepts_batched_logits():
    """Both modes share the input contract: any leading batch shape
    (the fused branch flattens to the kernel's (rows, V) view)."""
    ks = jax.random.split(KEY, 2)
    p = jax.random.normal(ks[0], (3, 5, 40)) * 2
    q = jax.random.normal(ks[1], (3, 5, 40)) * 2
    a = LS.softmax_kl(p, q)
    b = LS.softmax_kl(p, q, mode="fused", block_rows=4, block_v=32)
    assert b.shape == (3, 5)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_div_and_distill_loss_fused_match_ref():
    ks = jax.random.split(KEY, 2)
    p = jax.random.normal(ks[0], (16, 50)) * 2
    q = jax.random.normal(ks[1], (16, 50)) * 2
    np.testing.assert_allclose(float(LS.div_loss(p, q)),
                               float(LS.div_loss(p, q, mode="fused")),
                               atol=1e-6)
    np.testing.assert_allclose(
        float(LS.distill_loss(p, q)),
        float(LS.distill_loss(p, q, mode="fused", with_teacher_grad=False)),
        atol=1e-6)


def test_unknown_mode_raises():
    p = jnp.zeros((2, 4))
    with pytest.raises(ValueError, match="unknown distill_kl mode"):
        LS.softmax_kl(p, p, mode="nope")


# ------------------------------------------- CNN-scale server (dense) --

def _tiny_setup():
    from repro.configs.paper_cifar import smoke
    from repro.core.ensemble import Client
    from repro.models.cnn import CNNSpec, cnn_init
    scfg = dataclasses.replace(
        smoke(), n_clients=2, client_kinds=("cnn1", "cnn1"), t_g=1,
        epochs=1, synth_batch=16, nz=8, image_size=8)
    spec = CNNSpec(kind="cnn1", num_classes=scfg.num_classes, in_ch=3,
                   width=scfg.width, image_size=scfg.image_size)
    clients = [Client(spec=spec, params=cnn_init(jax.random.PRNGKey(i), spec))
               for i in range(scfg.n_clients)]
    return scfg, spec, clients


def test_dense_steps_fused_mode_matches_ref():
    from repro.core import generator as G
    from repro.core.dense import make_dense_steps
    from repro.models.cnn import cnn_init
    scfg, spec, clients = _tiny_setup()
    z = jax.random.normal(jax.random.PRNGKey(1), (16, scfg.nz))
    y = jax.random.randint(jax.random.PRNGKey(2), (16,), 0,
                           scfg.num_classes)
    outs = {}
    for mode in ("ref", "fused"):
        s2 = dataclasses.replace(scfg, distill_kl_mode=mode)
        gen_step, student_step, g_opt, s_opt, gparams, _, _ = \
            make_dense_steps(clients, spec, s2)
        gen_p = G.img_generator_init(jax.random.PRNGKey(0), nz=s2.nz,
                                     img_size=s2.image_size, out_ch=3)
        stu_p = cnn_init(jax.random.PRNGKey(5), spec)
        gp, _, gl, _ = gen_step(gen_p, g_opt.init(gen_p), stu_p, gparams,
                                z, y)
        sp, _, dl = student_step(stu_p, s_opt.init(stu_p), gp, gparams, z)
        outs[mode] = (float(gl), float(dl), sp)
    # L_div routes the generator step; L_dis the student step
    np.testing.assert_allclose(outs["ref"][0], outs["fused"][0], rtol=1e-6)
    np.testing.assert_allclose(outs["ref"][1], outs["fused"][1], rtol=1e-5)
    diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         outs["ref"][2], outs["fused"][2])
    assert max(jax.tree_util.tree_leaves(diffs)) < 1e-5


def test_make_dense_steps_rejects_unknown_mode():
    from repro.core.dense import make_dense_steps
    scfg, spec, clients = _tiny_setup()
    bad = dataclasses.replace(scfg, distill_kl_mode="pallas")
    with pytest.raises(ValueError, match="unknown distill_kl mode"):
        make_dense_steps(clients, spec, bad)


# -------------------------------------- LLM student step (launch path) --

def test_pod_distill_step_fused_matches_ref():
    from repro import optim
    from repro.configs.base import get_smoke_config
    from repro.launch.mesh import make_host_mesh
    from repro.launch.steps import make_distill_step
    from repro.models import transformer as T
    cfg = get_smoke_config("llama3.2-3b")
    mesh = make_host_mesh(1)
    stacked = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[T.init_model(jax.random.PRNGKey(i), cfg) for i in range(2)])
    stu = T.init_model(jax.random.PRNGKey(9), cfg)
    opt = optim.adam(1e-4)
    emb = jax.random.normal(jax.random.PRNGKey(3), (2, 32, cfg.d_model))
    results = {}
    for mode in ("ref", "fused"):
        state = {"params": stu, "opt": opt.init(stu),
                 "step": jnp.zeros((), jnp.int32)}
        with mesh:
            step = make_distill_step(cfg, mesh, n_clients=2,
                                     distill_kl_mode=mode)
            new_state, metrics = jax.jit(step)(state, stacked, emb)
        results[mode] = (float(metrics["dis_loss"]), new_state["params"])
    np.testing.assert_allclose(results["ref"][0], results["fused"][0],
                               rtol=1e-5)
    diffs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        results["ref"][1], results["fused"][1])
    assert max(jax.tree_util.tree_leaves(diffs)) < 1e-4


# ---------------------------------- kernel_vjp_mode (attention/SSM) --
#
# scfg.kernel_vjp_mode routing equivalence for the OTHER two §9 kernel
# pairs: "fused" (streaming custom-VJP flash_attention / ssd_scan) must
# reproduce "ref" (the pure-XLA model paths) through the dense_llm
# distillation steps — forward, backward and optimizer update.

def _pod_parity(arch, seq=24):
    from repro import optim
    from repro.configs.base import get_smoke_config
    from repro.launch.mesh import make_host_mesh
    from repro.launch.steps import make_distill_step
    from repro.models import transformer as T
    cfg = get_smoke_config(arch)
    mesh = make_host_mesh(1)
    stacked = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[T.init_model(jax.random.PRNGKey(i), cfg) for i in range(2)])
    stu = T.init_model(jax.random.PRNGKey(9), cfg)
    opt = optim.adam(1e-4)
    emb = jax.random.normal(jax.random.PRNGKey(3), (2, seq, cfg.d_model))
    results = {}
    for mode in ("ref", "fused"):
        state = {"params": stu, "opt": opt.init(stu),
                 "step": jnp.zeros((), jnp.int32)}
        with mesh:
            step = make_distill_step(cfg, mesh, n_clients=2,
                                     kernel_vjp_mode=mode)
            new_state, metrics = jax.jit(step)(state, stacked, emb)
        results[mode] = (float(metrics["dis_loss"]), new_state["params"])
    np.testing.assert_allclose(results["ref"][0], results["fused"][0],
                               rtol=1e-5)
    diffs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        results["ref"][1], results["fused"][1])
    assert max(jax.tree_util.tree_leaves(diffs)) < 1e-4


def test_pod_distill_step_kernel_vjp_fused_matches_ref_attention():
    """GQA trunk (llama): student backward runs through the streaming
    flash-attention custom-VJP pair under vmap'd clients + remat."""
    _pod_parity("llama3.2-3b")


def test_pod_distill_step_kernel_vjp_fused_matches_ref_ssm():
    """Mamba-2 trunk: student backward runs through the reversed-
    recurrence ssd_scan custom-VJP pair."""
    _pod_parity("mamba2-130m")


def test_llm_dense_steps_kernel_vjp_fused_matches_ref():
    """The heterogeneous steps: gen_step differentiates THROUGH the
    frozen clients' fused attention (generator gradients flow into
    dq/dk/dv), student_step through the student's."""
    from repro import optim  # noqa: F401
    from repro.configs.base import ArchConfig
    from repro.core import dense_llm as DL
    from repro.core.generator import tok_generator_init
    from repro.models import transformer as T
    cfg = ArchConfig(name="tiny", n_layers=1, d_model=32, n_heads=2,
                     n_kv_heads=1, head_dim=16, d_ff=64, vocab_size=64,
                     dtype="float32", param_dtype="float32", remat=False)
    cp = [T.init_model(jax.random.PRNGKey(i), cfg) for i in range(2)]
    stu0 = T.init_model(jax.random.PRNGKey(9), cfg)
    gen0 = tok_generator_init(jax.random.PRNGKey(5), nz=4, seq=8,
                              d_model=cfg.d_model, d_g=16,
                              n_classes=cfg.vocab_size)
    z = jax.random.normal(jax.random.PRNGKey(1), (2, 4))
    y = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0,
                           cfg.vocab_size)
    outs = {}
    for mode in ("ref", "fused"):
        gstep, sstep, g_opt, s_opt = DL.make_llm_dense_steps(
            cfg, [cfg, cfg], gen_seq=8, nz=4, kernel_vjp_mode=mode)
        gp, _, gl, _ = gstep(gen0, g_opt.init(gen0), stu0, cp, z, y)
        sp, _, dl = sstep(stu0, s_opt.init(stu0), gp, cp, z, y)
        outs[mode] = (float(gl), float(dl), gp, sp)
    np.testing.assert_allclose(outs["ref"][0], outs["fused"][0], rtol=1e-5)
    np.testing.assert_allclose(outs["ref"][1], outs["fused"][1], rtol=1e-5)
    for idx in (2, 3):
        diffs = jax.tree.map(
            lambda a, b: float(jnp.max(jnp.abs(a - b))),
            outs["ref"][idx], outs["fused"][idx])
        assert max(jax.tree_util.tree_leaves(diffs)) < 1e-5


def test_step_builders_reject_unknown_kernel_vjp_mode():
    from repro.configs.base import get_smoke_config
    from repro.core import dense_llm as DL
    from repro.launch.mesh import make_host_mesh
    cfg = get_smoke_config("llama3.2-3b")
    with pytest.raises(ValueError, match="unknown kernel_vjp mode"):
        DL.make_llm_dense_steps(cfg, [cfg], kernel_vjp_mode="pallas")
    with pytest.raises(ValueError, match="unknown kernel_vjp mode"):
        DL.make_pod_distill_step(cfg, make_host_mesh(1), n_clients=2,
                                 kernel_vjp_mode="nope")
    # "autodiff" is a valid ops-level serving mode but cannot train (jax
    # cannot differentiate the bare forward kernels): the step builders
    # fail fast instead of crashing deep inside grad tracing
    with pytest.raises(ValueError, match="cannot train"):
        DL.make_llm_dense_steps(cfg, [cfg], kernel_vjp_mode="autodiff")
    with pytest.raises(ValueError, match="cannot train"):
        DL.make_pod_distill_step(cfg, make_host_mesh(1), n_clients=2,
                                 kernel_vjp_mode="autodiff")
