"""hypothesis import shim.

The property-test suites use hypothesis when it is installed; on hosts
without it the whole module used to fail at *collection*, taking the
plain unit tests in the same files down with it. Importing ``given``,
``settings`` and ``st`` from here instead keeps collection working
everywhere: with hypothesis absent, ``@given(...)`` turns into a skip
marker and the strategy/settings surface becomes inert stubs, so only
the property tests are skipped.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def _stub(*args, **kwargs):
        """Absorbs any call chain (st.integers(...), st.composite(f),
        profile registration, ...) by returning itself."""
        return _stub

    class _Strategies:
        def __getattr__(self, name):
            return _stub

    st = _Strategies()

    class settings:  # noqa: N801 — mirrors hypothesis.settings
        register_profile = staticmethod(_stub)
        load_profile = staticmethod(_stub)

        def __init__(self, *args, **kwargs):
            pass

        def __call__(self, fn):
            return fn

    def given(*args, **kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")
