"""Client-axis mesh sharding (fl/sharding.py) + fedavg_stacked edges.

The sharded paths are placement/lowering choices, never math changes:
``ensemble_shard_mode="clients"`` must reproduce the single-device
grouped teacher logits and grouped local-update params to float
tolerance for the same seeds. These tests run at ANY device count — on
the plain tier-1 host the ("clients", "data") mesh is degenerate
(axis size 1) and they pin the routing; CI's ``sharding-equivalence``
job reruns them under XLA_FLAGS=--xla_force_host_platform_device_count=8
where the client axis genuinely splits across 8 devices (conftest.py
forbids forcing the device count in-process, so the multi-device regime
lives in the CI env, not here).
"""
import dataclasses
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.paper_cifar import DenseExperimentConfig
from repro.core import losses as LS
from repro.core.ensemble import (Client, ensemble_logits,
                                 grouped_ensemble_logits, split_clients,
                                 stack_grouped)
from repro.data.pipeline import build_batch_plan, pad_shards
from repro.fl import sharding as FS
from repro.fl.client import local_update_grouped
from repro.fl.fedavg import fedavg_stacked
from repro.launch.mesh import make_client_mesh
from repro.models.cnn import CNNSpec, cnn_init


def _tree_max_diff(a, b):
    return max(float(jnp.max(jnp.abs(x.astype(jnp.float32)
                                     - y.astype(jnp.float32))))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ------------------------------------------------------ mesh + spec unit ---

def test_make_client_mesh_axes():
    mesh = make_client_mesh()
    assert mesh.axis_names == ("clients", "data")
    n = len(jax.devices())
    assert dict(mesh.shape) == {"clients": n, "data": 1}
    mesh2 = make_client_mesh(data=n)        # all devices on the data axis
    assert dict(mesh2.shape) == {"clients": 1, "data": n}


def test_resolve_mesh_routing():
    assert FS.resolve_mesh(SimpleNamespace(ensemble_shard_mode="none")) is None
    assert FS.resolve_mesh(SimpleNamespace()) is None      # attr missing
    mesh = FS.resolve_mesh(SimpleNamespace(ensemble_shard_mode="clients"))
    assert mesh is not None and "clients" in mesh.axis_names
    with pytest.raises(ValueError):
        FS.resolve_mesh(SimpleNamespace(ensemble_shard_mode="pods"))


def test_group_shardable_divisibility():
    mesh8 = SimpleNamespace(shape={"clients": 8, "data": 1})
    assert FS.client_axis_size(mesh8) == 8
    assert FS.client_axis_size(None) == 1
    assert FS.group_shardable(mesh8, 8)
    assert FS.group_shardable(mesh8, 16)
    assert not FS.group_shardable(mesh8, 3)   # 3 % 8 != 0 -> replicate
    assert not FS.group_shardable(mesh8, 1)   # singletons never shard
    assert not FS.group_shardable(None, 8)


def test_stack_specs_shared_vocabulary():
    """The host 'clients' path and the LLM 'pod' path prepend the same
    leading client dim through one helper (fl.sharding.stack_specs)."""
    from repro.core.dense_llm import pod_stack_specs
    inner = {"w": P(None, "model"), "b": P()}
    got = FS.stack_specs(inner, "clients")
    assert got == {"w": P("clients", None, "model"), "b": P("clients")}
    pod_mesh = SimpleNamespace(axis_names=("pod", "data", "model"))
    host_mesh = SimpleNamespace(axis_names=("data", "model"))
    assert pod_stack_specs(inner, pod_mesh)["w"] == P("pod", None, "model")
    assert pod_stack_specs(inner, host_mesh)["w"] == P(None, None, "model")


# ------------------------------------------------- fedavg_stacked edges ---

def test_fedavg_stacked_single_client_group():
    sp = CNNSpec(kind="cnn1", num_classes=4, in_ch=1, width=0.25,
                 image_size=8)
    params = cnn_init(jax.random.PRNGKey(0), sp)
    stacked = jax.tree.map(lambda a: a[None], params)   # m=1 leading axis
    out = fedavg_stacked(stacked, [17])
    assert _tree_max_diff(out, params) == 0.0


def test_fedavg_stacked_zero_weight_rejection():
    stacked = {"w": jnp.ones((3, 2))}
    for bad in ([4, 0, 2], [4, -1, 2], []):
        with pytest.raises(ValueError):
            fedavg_stacked(stacked, bad)


def test_fedavg_stacked_dtype_preservation():
    stacked = {"w": jnp.ones((4, 8), jnp.bfloat16),
               "b": jnp.arange(4 * 3, dtype=jnp.float32).reshape(4, 3)}
    out = fedavg_stacked(stacked, [1, 1, 1, 1])
    assert out["w"].dtype == jnp.bfloat16
    assert out["b"].dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(out["b"]),
                               np.arange(12, dtype=np.float32)
                               .reshape(4, 3).mean(0), atol=1e-5)


def test_fedavg_stacked_on_client_sharded_params():
    """The stacked tree-reduce must accept client-sharded inputs (the
    grouped engine's output under ensemble_shard_mode='clients')."""
    mesh = make_client_mesh()
    m = 8
    stacked = {"w": jnp.arange(m * 4, dtype=jnp.float32).reshape(m, 4)}
    ref = fedavg_stacked(stacked, [2] * m)
    got = fedavg_stacked(FS.put_stacked(stacked, mesh, m), [2] * m)
    np.testing.assert_allclose(np.asarray(got["w"]), np.asarray(ref["w"]),
                               atol=1e-6)


# ------------------------------------- sharded-vs-unsharded equivalence ---

def _mk_clients(kinds, seed0=0, num_classes=6):
    out = []
    for i, k in enumerate(kinds):
        sp = CNNSpec(kind=k, num_classes=num_classes, in_ch=3, width=0.25,
                     image_size=8)
        out.append(Client(spec=sp,
                          params=cnn_init(jax.random.PRNGKey(seed0 + i), sp)))
    return out


@pytest.mark.parametrize("kinds", [("cnn1",) * 8,
                                   ("cnn1",) * 8 + ("cnn2",) * 8],
                         ids=["homog8", "hetero8+8"])
def test_sharded_ensemble_matches_unsharded(kinds):
    mesh = make_client_mesh()
    clients = _mk_clients(kinds)
    x = jax.random.normal(jax.random.PRNGKey(42), (8, 8, 8, 3))
    gspecs, gparams = stack_grouped(clients)
    ref, ref_stats = grouped_ensemble_logits(gspecs, gparams, x,
                                             with_bn_stats=True)
    gp_sh = FS.put_grouped(gspecs, gparams, mesh)
    got, got_stats = jax.jit(
        lambda gp, xb: grouped_ensemble_logits(gspecs, gp, xb,
                                               with_bn_stats=True,
                                               mesh=mesh))(gp_sh, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)
    assert len(got_stats) == len(kinds)
    np.testing.assert_allclose(float(LS.bn_loss(got_stats)),
                               float(LS.bn_loss(ref_stats)), rtol=1e-4)
    # and against the unrolled reference too
    specs, cparams = split_clients(clients)
    unrolled = ensemble_logits(specs, cparams, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(unrolled),
                               atol=1e-5)


def test_sharded_ensemble_nondivisible_group_falls_back():
    """A mesh whose clients axis does not divide the group size must give
    the unsharded answer (vmap fallback), not fail."""
    mesh = make_client_mesh()
    clients = _mk_clients(("cnn1",) * 3)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 8, 3))
    gspecs, gparams = stack_grouped(clients)
    ref = grouped_ensemble_logits(gspecs, gparams, x)
    gp_sh = FS.put_grouped(gspecs, gparams, mesh)
    got = grouped_ensemble_logits(gspecs, gp_sh, x, mesh=mesh)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)


def test_sharded_local_update_matches_unsharded():
    mesh = make_client_mesh()
    m, batch, epochs = 8, 8, 2
    rng = np.random.default_rng(0)
    spec = CNNSpec(kind="cnn1", num_classes=6, in_ch=3, width=0.25,
                   image_size=8)
    # ragged shards: masking + padding steps must survive sharding
    shards = [(rng.standard_normal((18 + 3 * k, 8, 8, 3))
               .astype(np.float32), rng.integers(0, 6, 18 + 3 * k))
              for k in range(m)]
    inits = [cnn_init(jax.random.PRNGKey(i), spec) for i in range(m)]
    stacked0 = jax.tree.map(lambda *a: jnp.stack(a), *inits)
    xs, ys = pad_shards(shards)
    plan = build_batch_plan([len(y) for _, y in shards], batch,
                            epochs=epochs, seeds=list(range(m)))
    ref, _ = local_update_grouped(jax.tree.map(jnp.copy, stacked0), spec,
                                  xs, ys, plan, num_classes=6)
    got, _ = local_update_grouped(jax.tree.map(jnp.copy, stacked0), spec,
                                  xs, ys, plan, num_classes=6, mesh=mesh)
    assert _tree_max_diff(got, ref) < 1e-6


SCFG = DenseExperimentConfig(
    n_clients=8, alpha=0.5, local_epochs=2, batch_size=16, num_classes=4,
    image_size=8, in_ch=3, train_per_class=24, test_per_class=8,
    client_kinds=("cnn1",) * 8, global_kind="cnn1", width=0.25, nz=16,
    t_g=2, epochs=3, synth_batch=16)


@pytest.mark.parametrize("kinds", [("cnn1",), ("cnn1", "cnn2")],
                         ids=["homog", "hetero2"])
def test_federation_shard_mode_equivalence(kinds):
    """ensemble_shard_mode='clients' end-to-end: same Dirichlet
    partition, same seeds -> identical trained client params (grouped
    local phase is placement-only SPMD). hetero2 cycles two kinds over 16
    clients -> two stacked groups of 8, both sharded on the 8-device CI
    mesh."""
    from repro.data import make_classification_data
    from repro.fl.protocol import build_federation
    scfg = dataclasses.replace(SCFG, n_clients=8 * len(kinds),
                               client_kinds=kinds * 8)
    data = make_classification_data(0, num_classes=scfg.num_classes,
                                    size=scfg.image_size, ch=scfg.in_ch,
                                    train_per_class=scfg.train_per_class,
                                    test_per_class=scfg.test_per_class)
    built = {}
    for mode in ("none", "clients"):
        s = dataclasses.replace(scfg, ensemble_shard_mode=mode)
        built[mode], _ = build_federation(jax.random.PRNGKey(0), s, data,
                                          seed=0)
    for ca, cb in zip(built["none"], built["clients"]):
        assert ca.spec == cb.spec
        assert _tree_max_diff(ca.params, cb.params) < 1e-6


def test_dense_server_shard_mode_equivalence():
    """The teacher under ensemble_shard_mode='clients' (psum-lowered
    logit mean) must train the same student as the single-device grouped
    path for the same key stream."""
    from repro.core import train_dense_server
    clients = _mk_clients(("cnn1",) * 8, num_classes=SCFG.num_classes)
    outs = {}
    for mode in ("none", "clients"):
        s = dataclasses.replace(SCFG, ensemble_shard_mode=mode)
        stu, _, hist = train_dense_server(jax.random.PRNGKey(3), clients, s)
        outs[mode] = (stu, hist)
    assert _tree_max_diff(outs["none"][0], outs["clients"][0]) < 5e-5
    np.testing.assert_allclose(outs["none"][1].gen_loss,
                               outs["clients"][1].gen_loss,
                               rtol=1e-3, atol=1e-5)
