"""Property tests for the DENSE loss functions (paper Eqs. 2-6)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import losses as LS

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def logits_pair(draw, rows=4, classes=8, scale=5.0):
    a = draw(st.integers(0, 2 ** 31 - 1))
    k1, k2 = jax.random.split(jax.random.PRNGKey(a))
    return (jax.random.normal(k1, (rows, classes)) * scale,
            jax.random.normal(k2, (rows, classes)) * scale)


@st.composite
def _pairs(draw):
    return logits_pair(draw)


@given(_pairs())
def test_kl_nonnegative(pair):
    p, q = pair
    kl = LS.softmax_kl(p, q)
    assert np.all(np.asarray(kl) >= -1e-5)


@given(_pairs())
def test_kl_self_zero(pair):
    p, _ = pair
    kl = LS.softmax_kl(p, p)
    np.testing.assert_allclose(np.asarray(kl), 0.0, atol=1e-5)


@given(_pairs())
def test_distill_loss_is_mean_kl(pair):
    p, q = pair
    np.testing.assert_allclose(float(LS.distill_loss(p, q)),
                               float(jnp.mean(LS.softmax_kl(p, q))),
                               rtol=1e-6)


@given(_pairs())
def test_div_loss_nonpositive_and_zero_on_agreement(pair):
    p, q = pair
    # Eq. 4 is -omega*KL <= 0
    assert float(LS.div_loss(p, q)) <= 1e-6
    # when argmaxes agree everywhere, omega = 0 -> loss exactly 0
    assert float(LS.div_loss(p, p + 0.0)) == pytest.approx(0.0, abs=1e-7)


@given(_pairs())
def test_ce_loss_matches_manual(pair):
    p, _ = pair
    y = jnp.arange(p.shape[0]) % p.shape[1]
    manual = -jnp.mean(jax.nn.log_softmax(p, -1)[jnp.arange(p.shape[0]), y])
    np.testing.assert_allclose(float(LS.ce_loss(p, y)), float(manual),
                               rtol=1e-6)


def test_bn_loss_zero_when_stats_match():
    stats = [[{"mean": jnp.ones(4), "var": jnp.full(4, 2.0),
               "running_mean": jnp.ones(4), "running_var": jnp.full(4, 2.0)}]]
    assert float(LS.bn_loss(stats)) == 0.0


def test_bn_loss_positive_on_mismatch_and_averages_over_clients():
    one = [{"mean": jnp.zeros(4), "var": jnp.ones(4),
            "running_mean": jnp.ones(4), "running_var": jnp.ones(4)}]
    l1 = float(LS.bn_loss([one]))
    l2 = float(LS.bn_loss([one, one]))
    assert l1 > 0
    np.testing.assert_allclose(l1, l2, rtol=1e-6)  # (1/m) sum_k


def test_gen_loss_combines_terms():
    p = jnp.array([[2.0, -1.0, 0.0]])
    q = jnp.array([[-1.0, 2.0, 0.0]])
    y = jnp.array([0])
    stats = [[{"mean": jnp.zeros(2), "var": jnp.ones(2),
               "running_mean": jnp.ones(2), "running_var": jnp.ones(2)}]]
    total, parts = LS.gen_loss(p, y, stats, q, lambda_bn=2.0, lambda_div=0.5)
    expect = parts["ce"] + 2.0 * parts["bn"] + 0.5 * parts["div"]
    np.testing.assert_allclose(float(total), float(expect), rtol=1e-6)
