import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: never set xla_force_host_platform_device_count here — smoke tests
# and benches must see the real (single) device; only launch/dryrun.py
# requests 512 placeholder devices (as its first import lines). Importing
# that module from a test is harmless because we lock the backend to the
# default device count right away:
import jax  # noqa: E402

jax.devices()

# The `slow` marker (scripts/tier1.sh --fast runs `-m "not slow"`) is
# registered in pyproject.toml [tool.pytest.ini_options], paired with
# --strict-markers — not here, so a typo there fails loudly instead of
# being masked by a duplicate registration.
