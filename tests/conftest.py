import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: never set xla_force_host_platform_device_count here — smoke tests
# and benches must see the real (single) device; only launch/dryrun.py
# requests 512 placeholder devices (as its first import lines). Importing
# that module from a test is harmless because we lock the backend to the
# default device count right away:
import jax  # noqa: E402

jax.devices()


def pytest_configure(config):
    # scripts/tier1.sh --fast runs `-m "not slow"`: mark multi-config
    # equivalence sweeps (grouped-vs-python local training & co) slow so
    # the fast gate stays within a tight time budget.
    config.addinivalue_line(
        "markers", "slow: long equivalence sweep; excluded by "
                   "scripts/tier1.sh --fast")
