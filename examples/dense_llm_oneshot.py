"""DENSE at LM scale (reduced configs): one-shot federation of *decoder
language models* with heterogeneous architectures (llama-style + qwen-style
+ phi-style), distilled into a global student — the LLM instantiation of
the paper described in DESIGN.md §3/§7.

Clients train on disjoint shards of a Markov token stream (non-IID via
different transition tables), upload once, then the server runs the two
DENSE stages with a token-sequence generator emitting soft embeddings.

  PYTHONPATH=src python examples/dense_llm_oneshot.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_smoke_config
from repro.core import dense_llm as DL
from repro.core.generator import tok_generator_init
from repro.data import lm_batches, make_lm_data
from repro.fl.protocol import param_bytes
from repro.launch import steps as ST
from repro.models import transformer as T

VOCAB = 256
SEQ = 32


def train_client(arch: str, seed: int, steps: int = 40):
    cfg = get_smoke_config(arch).replace(vocab_size=VOCAB)
    state = ST.make_train_state(jax.random.PRNGKey(seed), cfg, lr=3e-3)
    step = jax.jit(ST.make_train_step(cfg, None, lr=3e-3))
    toks = make_lm_data(seed, vocab=VOCAB, n_tokens=40_000)  # disjoint dialect
    for x, y in lm_batches(toks, 8, SEQ, seed=seed, steps=steps):
        state, m = step(state, {"tokens": jnp.asarray(x),
                                "labels": jnp.asarray(y)})
    return cfg, state["params"], float(m["loss"])


def main():
    archs = ["llama3.2-3b", "qwen1.5-4b", "musicgen-large"]
    cfgs, params, up = [], [], 0
    for i, a in enumerate(archs):
        cfg, p, loss = train_client(a, seed=i)
        cfgs.append(cfg)
        params.append(p)
        up += param_bytes(p)
        print(f"client[{a}] local LM loss {loss:.3f}")
    print(f"one-shot upload: {up/1e6:.1f} MB, 1 round")

    stu_cfg = get_smoke_config("phi3-medium-14b").replace(vocab_size=VOCAB)
    key = jax.random.PRNGKey(99)
    stu_p = T.init_model(key, stu_cfg)
    gen_p = tok_generator_init(key, nz=16, seq=SEQ, d_model=stu_cfg.d_model,
                               d_g=64, n_classes=VOCAB)
    gstep, sstep, g_opt, s_opt = DL.make_llm_dense_steps(
        stu_cfg, cfgs, gen_seq=SEQ, nz=16, s_lr=3e-4)
    gs, ss = g_opt.init(gen_p), s_opt.init(stu_p)

    for epoch in range(12):
        key, kz, ky = jax.random.split(key, 3)
        z = jax.random.normal(kz, (8, 16))
        y = jax.random.randint(ky, (8, SEQ), 0, VOCAB)
        for _ in range(3):
            gen_p, gs, gl, parts = gstep(gen_p, gs, stu_p, params, z, y)
        stu_p, ss, dl = sstep(stu_p, ss, gen_p, params, z, y)
        if (epoch + 1) % 3 == 0:
            print(f"epoch {epoch+1:2d} gen={float(gl):7.3f} "
                  f"(ce={float(parts['ce']):.3f} bn={float(parts['bn']):.3f} "
                  f"div={float(parts['div']):.3f}) distill_kl={float(dl):.4f}")
    print("done: global student distilled from a heterogeneous LM ensemble "
          "with one communication round and no data.")


if __name__ == "__main__":
    main()
