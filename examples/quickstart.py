"""Quickstart: data-free one-shot FL with DENSE in ~6 minutes on CPU.

Builds a 3-client non-IID federation on procedural image data, trains the
clients locally, uploads their models ONCE (the single communication round),
and runs DENSE's two server stages. Compares against one-shot FedAvg.

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

import jax

from repro.configs.paper_cifar import smoke
from repro.core import evaluate, train_dense_server
from repro.data import make_classification_data
from repro.fl import CommLedger, build_federation, fedavg


def main():
    scfg = dataclasses.replace(smoke(), epochs=80, t_g=5, s_steps=8)
    print(f"federation: {scfg.n_clients} clients, Dirichlet α={scfg.alpha}")

    data = make_classification_data(
        0, num_classes=scfg.num_classes, size=scfg.image_size,
        ch=scfg.in_ch, train_per_class=scfg.train_per_class,
        test_per_class=scfg.test_per_class)
    xt, yt = data["test"]

    # --- the one and only communication round -------------------------
    ledger = CommLedger()
    clients, _ = build_federation(jax.random.PRNGKey(0), scfg, data,
                                  ledger=ledger)
    print(f"one-shot upload: {ledger.uplink_bytes/1e6:.2f} MB total, "
          f"{ledger.rounds} round, downlink={ledger.downlink_bytes} B")
    for i, c in enumerate(clients):
        print(f"  client{i}: n={c.n_data:4d} "
              f"local acc={evaluate(c.params, c.spec, xt, yt):.3f}")

    # --- baseline: parameter averaging ---------------------------------
    acc_avg = evaluate(fedavg(clients), clients[0].spec, xt, yt)
    print(f"one-shot FedAvg acc: {acc_avg:.3f}")

    # --- DENSE: generator stage + distillation stage -------------------
    stu, gen, hist = train_dense_server(jax.random.PRNGKey(1), clients, scfg)
    acc = evaluate(stu, clients[0].spec, xt, yt)
    print(f"DENSE global model acc: {acc:.3f}")
    print(f"generator losses (last epoch): "
          f"CE={hist.gen_parts[-1]['ce']:.3f} "
          f"BN={hist.gen_parts[-1]['bn']:.3f} "
          f"div={hist.gen_parts[-1]['div']:.3f}")


if __name__ == "__main__":
    main()
