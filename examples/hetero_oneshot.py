"""Heterogeneous one-shot FL (paper Table 2): every client has a DIFFERENT
architecture, so FedAvg is impossible — DENSE distills the mixed ensemble
into a server-chosen global model.

  PYTHONPATH=src python examples/hetero_oneshot.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

import jax

from repro.configs.paper_cifar import smoke
from repro.core import evaluate, train_dense_server
from repro.data import make_classification_data
from repro.fl import build_federation, fedavg


def main():
    scfg = dataclasses.replace(
        smoke(), n_clients=3, client_kinds=("cnn1", "cnn2", "wrn16_1"),
        global_kind="wrn16_1", epochs=30, t_g=4, s_steps=6)
    data = make_classification_data(
        1, num_classes=scfg.num_classes, size=scfg.image_size,
        ch=scfg.in_ch, train_per_class=scfg.train_per_class,
        test_per_class=scfg.test_per_class)
    xt, yt = data["test"]
    clients, _ = build_federation(jax.random.PRNGKey(0), scfg, data)
    for c in clients:
        print(f"client arch={c.spec.kind:9s} n={c.n_data:4d} "
              f"acc={evaluate(c.params, c.spec, xt, yt):.3f}")

    try:
        fedavg(clients)
    except ValueError as e:
        print(f"FedAvg refuses (as it must): {e}")

    stu, _, _ = train_dense_server(jax.random.PRNGKey(1), clients, scfg)
    spec = dataclasses.replace(clients[0].spec, kind=scfg.global_kind)
    print(f"DENSE global ({scfg.global_kind}) acc: "
          f"{evaluate(stu, spec, xt, yt):.3f}")


if __name__ == "__main__":
    main()
