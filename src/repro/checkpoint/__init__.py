from repro.checkpoint.io import (checkpoint_exists, load_meta,
                                 restore_checkpoint, save_checkpoint)

__all__ = ["checkpoint_exists", "save_checkpoint", "restore_checkpoint",
           "load_meta"]
