from repro.checkpoint.io import save_checkpoint, restore_checkpoint, load_meta

__all__ = ["save_checkpoint", "restore_checkpoint", "load_meta"]
