"""npz-based pytree checkpointing with path-flattened keys + JSON metadata."""
from __future__ import annotations

import json
import os

import jax
import numpy as np


def _flatten(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_seg(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _seg(p):
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


def save_checkpoint(path: str, tree, meta: dict | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    np.savez(path if path.endswith(".npz") else path + ".npz", **flat)
    if meta is not None:
        with open(path.removesuffix(".npz") + ".json", "w") as f:
            json.dump(meta, f, indent=2, default=str)


def restore_checkpoint(path: str, like):
    """Restore into the structure of ``like`` (same treedef)."""
    f = np.load(path if path.endswith(".npz") else path + ".npz")
    flat_like = _flatten(like)
    assert set(f.files) == set(flat_like), (
        f"checkpoint keys mismatch: {set(f.files) ^ set(flat_like)}")
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    keys = ["/".join(_seg(p) for p in path)
            for path, _ in jax.tree_util.tree_flatten_with_path(like)[0]]
    new_leaves = [f[k] for k in keys]
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def load_meta(path: str) -> dict:
    with open(path.removesuffix(".npz") + ".json") as f:
        return json.load(f)
