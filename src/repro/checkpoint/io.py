"""npz-based pytree checkpointing with path-flattened keys + JSON metadata.

Used by the DENSE server loop's periodic checkpoint/resume
(core/dense.train_dense_server, ``scfg.checkpoint_every`` /
``scfg.checkpoint_path``): a killed run restores the full server state
(generator/student params, optimizer states, epoch index, base key) and
replays the remaining epochs bit-identically (tests/test_checkpoint.py).
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np


def _flatten(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_seg(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _seg(p):
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


def checkpoint_exists(path: str) -> bool:
    return os.path.exists(path if path.endswith(".npz") else path + ".npz")


def save_checkpoint(path: str, tree, meta: dict | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    np.savez(path if path.endswith(".npz") else path + ".npz", **flat)
    if meta is not None:
        with open(path.removesuffix(".npz") + ".json", "w") as f:
            json.dump(meta, f, indent=2, default=str)


def restore_checkpoint(path: str, like):
    """Restore into the structure of ``like`` (same treedef).

    Raises ``ValueError`` (not a bare assert — must survive ``python -O``)
    when the checkpoint's key set does not match ``like``'s flattened
    paths. Restored leaves are cast to the corresponding ``like`` leaf's
    dtype, so optimizer step counters, PRNG keys and mixed-precision
    params come back exactly as the run left them regardless of how
    ``np.savez`` round-tripped the storage dtype.
    """
    fname = path if path.endswith(".npz") else path + ".npz"
    flat_like = _flatten(like)
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    keys = ["/".join(_seg(p) for p in path)
            for path, _ in jax.tree_util.tree_flatten_with_path(like)[0]]
    with np.load(fname) as f:          # context manager: no leaked fd
        if set(f.files) != set(flat_like):
            raise ValueError(
                f"checkpoint keys mismatch vs `like` treedef: "
                f"{sorted(set(f.files) ^ set(flat_like))}")
        new_leaves = [np.asarray(f[k]).astype(np.asarray(l).dtype)
                      for k, l in zip(keys, leaves_like)]
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def load_meta(path: str) -> dict:
    with open(path.removesuffix(".npz") + ".json") as f:
        return json.load(f)
