"""Learning-rate schedules as callables of the (int) step."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: lr


def cosine(lr: float, total_steps: int, final_frac: float = 0.0):
    def f(step):
        t = jnp.minimum(step, total_steps) / max(total_steps, 1)
        c = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return lr * (final_frac + (1 - final_frac) * c)
    return f


def warmup_cosine(lr: float, warmup: int, total_steps: int,
                  final_frac: float = 0.0):
    cos = cosine(lr, max(total_steps - warmup, 1), final_frac)
    def f(step):
        w = jnp.minimum(step / max(warmup, 1), 1.0)
        return jnp.where(step < warmup, lr * w, cos(step - warmup))
    return f
