"""Minimal pure-JAX optimizers (optax is not available offline)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(tree, max_norm: float):
    n = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        tree), n


class sgd:
    """SGD with (heavy-ball) momentum, matching torch.optim.SGD semantics
    (the paper's client optimizer: lr=0.01, momentum=0.9)."""

    def __init__(self, lr, momentum: float = 0.0, weight_decay: float = 0.0):
        self.lr, self.momentum, self.wd = lr, momentum, weight_decay

    def init(self, params):
        if self.momentum == 0.0:
            return ()
        return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)

    def update(self, grads, state, params, step=0):
        lr = self.lr(step) if callable(self.lr) else self.lr
        if self.wd:
            grads = jax.tree.map(
                lambda g, p: g + self.wd * p.astype(g.dtype), grads, params)
        if self.momentum == 0.0:
            new_p = jax.tree.map(
                lambda p, g: (p.astype(jnp.float32)
                              - lr * g.astype(jnp.float32)).astype(p.dtype),
                params, grads)
            return new_p, ()
        new_state = jax.tree.map(
            lambda m, g: self.momentum * m + g.astype(jnp.float32),
            state, grads)
        new_p = jax.tree.map(
            lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype),
            params, new_state)
        return new_p, new_state


class adam:
    """Adam (the paper's generator optimizer: lr=1e-3)."""

    def __init__(self, lr, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0):
        self.lr, self.b1, self.b2, self.eps, self.wd = lr, b1, b2, eps, weight_decay

    def init(self, params):
        z = lambda: jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return {"m": z(), "v": z(), "t": jnp.zeros((), jnp.int32)}

    def update(self, grads, state, params, step=None):
        t = state["t"] + 1
        lr = self.lr(t) if callable(self.lr) else self.lr
        if self.wd:
            grads = jax.tree.map(
                lambda g, p: g + self.wd * p.astype(g.dtype), grads, params)
        m = jax.tree.map(lambda m_, g: self.b1 * m_ + (1 - self.b1)
                         * g.astype(jnp.float32), state["m"], grads)
        v = jax.tree.map(lambda v_, g: self.b2 * v_ + (1 - self.b2)
                         * jnp.square(g.astype(jnp.float32)), state["v"], grads)
        bc1 = 1 - self.b1 ** t.astype(jnp.float32)
        bc2 = 1 - self.b2 ** t.astype(jnp.float32)
        new_p = jax.tree.map(
            lambda p, m_, v_: (p.astype(jnp.float32)
                               - lr * (m_ / bc1)
                               / (jnp.sqrt(v_ / bc2) + self.eps)).astype(p.dtype),
            params, m, v)
        return new_p, {"m": m, "v": v, "t": t}
