"""LDAM loss (Cao et al. 2019) — the paper combines it with DENSE
(Table 4, DENSE+LDAM) to handle locally imbalanced client data."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def class_margins(class_counts: jnp.ndarray, max_margin: float = 0.5):
    """m_c proportional to n_c^{-1/4}, normalized so max(m) = max_margin."""
    counts = jnp.maximum(class_counts.astype(jnp.float32), 1.0)
    m = 1.0 / jnp.sqrt(jnp.sqrt(counts))
    return m * (max_margin / jnp.max(m))


def ldam_loss(logits: jnp.ndarray, labels: jnp.ndarray,
              margins: jnp.ndarray, s: float = 30.0,
              sample_mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Margin-adjusted CE: subtract m_y from the true-class logit, scale by s.

    sample_mask ((B,) bool, optional): mean over valid rows only — the
    grouped ragged-batch path. None is the plain batch mean."""
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    adj = logits - onehot * margins[None, :].astype(logits.dtype)
    logp = jax.nn.log_softmax(s * adj, axis=-1)
    nll = -jnp.sum(onehot * logp, axis=-1)
    if sample_mask is None:
        return jnp.mean(nll)
    w = sample_mask.astype(nll.dtype)
    return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)
