from repro.optim.optimizers import (adam, sgd, clip_by_global_norm,
                                    global_norm)
from repro.optim.schedules import constant, cosine, warmup_cosine
from repro.optim.ldam import ldam_loss, class_margins

__all__ = ["adam", "sgd", "clip_by_global_norm", "global_norm",
           "constant", "cosine", "warmup_cosine", "ldam_loss",
           "class_margins"]
