"""Mixture-of-Experts layer (DeepSeek-V2 style: shared + routed top-k).

Dispatch strategy
-----------------
Activations are replicated across the `model` mesh axis (Megatron layout),
experts are sharded over it (expert parallelism).  Each model shard routes
the full local-token block to *its* experts with a sort-free scatter/gather
dispatch (capacity-bounded), computes them as one batched matmul, and the
per-shard partial outputs are summed with a single ``psum`` over the expert
axis — the same collective cost as a Megatron MLP all-reduce, with zero
dispatch FLOPs (no GShard one-hot einsums, whose contraction FLOPs would
dwarf the expert compute at 160 experts x top-6).

The same local routine ``_moe_local`` runs unsharded on CPU (smoke tests)
and inside ``shard_map`` on the production mesh.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.configs.base import ArchConfig


def moe_init(key, cfg: ArchConfig, *, dtype) -> dict:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    ks = jax.random.split(key, 5)
    scale = 1.0 / jnp.sqrt(d)
    p = {
        "router": {"w": (jax.random.normal(ks[0], (d, e)) * scale
                         ).astype(jnp.float32)},  # router kept fp32
        "gate": (jax.random.normal(ks[1], (e, d, f)) * scale).astype(dtype),
        "up": (jax.random.normal(ks[2], (e, d, f)) * scale).astype(dtype),
        "down": (jax.random.normal(ks[3], (e, f, d)) * (1.0 / jnp.sqrt(f))
                 ).astype(dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = L.swiglu_init(ks[4], d, cfg.n_shared_experts * f,
                                    dtype=dtype)
    return p


def _capacity(n_tokens: int, cfg: ArchConfig) -> int:
    c = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts) + 1
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8


def _moe_local(xf, router_w, w_gate, w_up, w_down, *, cfg: ArchConfig,
               offset, e_local: int, capacity: int):
    """Route a flat token block through the local expert slice.

    xf: (T, D).  w_*: (e_local, ...).  offset: global id of first local
    expert (traced ok).  Returns (y:(T,D) partial sum over local experts,
    aux load-balance scalar computed from the full router distribution).
    """
    T, D = xf.shape
    k, E = cfg.top_k, cfg.n_experts
    probs = jax.nn.softmax(
        (xf.astype(jnp.float32) @ router_w).astype(jnp.float32), axis=-1)
    gate, idx = jax.lax.top_k(probs, k)                     # (T, k)
    gate = gate / jnp.sum(gate, -1, keepdims=True)

    # load-balance auxiliary (switch-style): E * sum_e f_e * p_e
    f_e = jnp.mean(jax.nn.one_hot(idx, E, dtype=jnp.float32), axis=(0, 1))
    p_e = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(f_e * p_e)

    flat_e = idx.reshape(-1)                                # (T*k,)
    flat_g = gate.reshape(-1).astype(xf.dtype)
    token_ids = jnp.arange(T * k, dtype=jnp.int32) // k

    local_e = flat_e - offset
    mine = (local_e >= 0) & (local_e < e_local)
    e_cl = jnp.where(mine, local_e, e_local)                # drop bucket

    # position of each assignment inside its expert (cumsum over one-hot)
    onehot = (e_cl[:, None] == jnp.arange(e_local + 1)[None, :])
    pos_all = jnp.cumsum(onehot.astype(jnp.int32), axis=0) - 1
    pos = jnp.take_along_axis(pos_all, e_cl[:, None], 1)[:, 0]
    keep = mine & (pos < capacity)
    e_sc = jnp.where(keep, e_cl, e_local)  # out-of-range rows are dropped

    # slot -> token map; unfilled slots point at the zero-pad row T
    slot_tok = jnp.full((e_local, capacity), T, jnp.int32)
    slot_tok = slot_tok.at[e_sc, pos].set(token_ids, mode="drop")
    slot_gate = jnp.zeros((e_local, capacity), xf.dtype)
    slot_gate = slot_gate.at[e_sc, pos].set(flat_g, mode="drop")

    x_pad = jnp.concatenate([xf, jnp.zeros((1, D), xf.dtype)], 0)
    xd = x_pad[slot_tok]                                    # (e, C, D) gather

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xd, w_gate.astype(xf.dtype))) \
        * jnp.einsum("ecd,edf->ecf", xd, w_up.astype(xf.dtype))
    out = jnp.einsum("ecf,efd->ecd", h, w_down.astype(xf.dtype))
    out = out * slot_gate[..., None]

    y = jnp.zeros((T + 1, D), xf.dtype)
    y = y.at[slot_tok.reshape(-1)].add(out.reshape(-1, D))
    return y[:T], aux


def moe_apply(p: dict, x: jnp.ndarray, cfg: ArchConfig, *,
              mesh=None, ep_axis: str = "model",
              dp_axes: tuple[str, ...] = ()):
    """x: (B, S, D) -> (y, aux). Sharded iff a mesh with `ep_axis` is given."""
    B, S, D = x.shape
    xf = x.reshape(B * S, D)
    E = cfg.n_experts

    if mesh is None or ep_axis not in getattr(mesh, "axis_names", ()):
        cap = _capacity(xf.shape[0], cfg)
        y, aux = _moe_local(xf, p["router"]["w"], p["gate"], p["up"],
                            p["down"], cfg=cfg, offset=0, e_local=E,
                            capacity=cap)
    else:
        n_shards = mesh.shape[ep_axis]
        e_local = E // n_shards
        t_local = xf.shape[0] // _dp_size(mesh, dp_axes)
        cap = _capacity(t_local, cfg)

        def f(xb, rw, wg, wu, wd):
            off = jax.lax.axis_index(ep_axis) * e_local
            y, aux = _moe_local(xb, rw, wg, wu, wd, cfg=cfg, offset=off,
                                e_local=e_local, capacity=cap)
            y = jax.lax.psum(y, ep_axis)
            aux = jax.lax.pmean(aux, (*dp_axes, ep_axis))
            return y, aux

        dspec = P(dp_axes if dp_axes else None, None)
        y, aux = jax.shard_map(
            f, mesh=mesh,
            in_specs=(dspec, P(), P(ep_axis), P(ep_axis), P(ep_axis)),
            out_specs=(dspec, P()), check_vma=False,
        )(xf, p["router"]["w"], p["gate"], p["up"], p["down"])

    y = y.reshape(B, S, D)
    if "shared" in p:
        y = y + L.swiglu(p["shared"], x)
    return y, aux


def _dp_size(mesh, dp_axes) -> int:
    n = 1
    for a in dp_axes:
        n *= mesh.shape[a]
    return n
