"""Model assembly for all assigned architecture families.

Families: dense (llama/qwen/phi/gemma/musicgen), moe (deepseek-v2 MLA+MoE),
ssm (mamba2), hybrid (zamba2), vlm (llama-3.2-vision).

Layer stacks are scanned (``lax.scan`` over stacked params) so the HLO holds
one compiled block body regardless of depth — essential for compile time on
the production mesh and for the 1-core CPU dry-run host.

Public API:
  init_model(key, cfg)            -> params
  init_cache(cfg, batch, max_len) -> cache pytree (decode/prefill)
  forward(params, cfg, ...)       -> (logits, new_cache, aux)
  loss_fn(params, cfg, batch, ...)-> (scalar, aux dict)
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import attention as A
from repro.models import moe as M
from repro.models import ssm as S


def _scan(f, init, xs, *, use_scan: bool = True):
    """lax.scan or an unrolled python loop (identical semantics).

    The unrolled form exists for the dry-run: XLA's cost_analysis counts a
    ``while`` body once, so scanned stacks under-report FLOPs/bytes/
    collective traffic by ~n_layers x. Roofline extraction compiles small
    unrolled depth variants instead (launch/dryrun.py)."""
    if use_scan:
        return jax.lax.scan(f, init, xs)
    n = jax.tree_util.tree_leaves(xs)[0].shape[0]
    carry, ys = init, []
    for i in range(n):
        x_i = jax.tree_util.tree_map(lambda a: a[i], xs)
        carry, y = f(carry, x_i)
        ys.append(y)
    ys = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *ys)
    return carry, ys


def _dt(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


def _pdt(cfg: ArchConfig):
    return jnp.dtype(cfg.param_dtype)


# ------------------------------------------------------------- block inits

def _dense_block_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {"norm1": L.rmsnorm_init(cfg.d_model, dtype),
            "attn": A.gqa_init(k1, cfg, dtype=dtype),
            "norm2": L.rmsnorm_init(cfg.d_model, dtype),
            "mlp": L.swiglu_init(k2, cfg.d_model, cfg.d_ff, dtype=dtype)}


def _moe_block_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    attn = (A.mla_init(k1, cfg, dtype=dtype) if cfg.kv_lora_rank
            else A.gqa_init(k1, cfg, dtype=dtype))
    return {"norm1": L.rmsnorm_init(cfg.d_model, dtype), "attn": attn,
            "norm2": L.rmsnorm_init(cfg.d_model, dtype),
            "moe": M.moe_init(k2, cfg, dtype=dtype)}


def _dense_mlp_block_init(key, cfg, dtype):
    """DeepSeek layer 0: MLA attention + dense MLP sized to active experts."""
    k1, k2 = jax.random.split(key)
    d_ff = cfg.d_ff_expert * (cfg.top_k + cfg.n_shared_experts)
    attn = (A.mla_init(k1, cfg, dtype=dtype) if cfg.kv_lora_rank
            else A.gqa_init(k1, cfg, dtype=dtype))
    return {"norm1": L.rmsnorm_init(cfg.d_model, dtype), "attn": attn,
            "norm2": L.rmsnorm_init(cfg.d_model, dtype),
            "mlp": L.swiglu_init(k2, cfg.d_model, d_ff, dtype=dtype)}


def _ssm_block_init(key, cfg, dtype):
    return {"norm": L.rmsnorm_init(cfg.d_model, dtype),
            "mamba": S.mamba2_init(key, cfg, dtype=dtype)}


def _cross_block_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {"norm1": L.rmsnorm_init(cfg.d_model, dtype),
            "xattn": A.cross_attn_init(k1, cfg, dtype=dtype),
            "norm2": L.rmsnorm_init(cfg.d_model, dtype),
            "mlp": L.swiglu_init(k2, cfg.d_model, cfg.d_ff, dtype=dtype),
            "mlp_gate": jnp.zeros((), dtype)}


def _stack_init(fn, key, n, *args):
    return jax.vmap(lambda k: fn(k, *args))(jax.random.split(key, n))


# --------------------------------------------------------------- topology

def layer_windows(cfg: ArchConfig) -> np.ndarray:
    """Per-layer sliding-window size; 0 = global. gemma3: 5 local : 1 global."""
    if not cfg.sliding_window:
        return np.zeros((cfg.n_layers,), np.int32)
    w = np.full((cfg.n_layers,), cfg.sliding_window, np.int32)
    if cfg.global_every:
        w[cfg.global_every - 1::cfg.global_every] = 0
    return w


def _hybrid_shape(cfg):
    n_super = cfg.n_layers // cfg.attn_every
    tail = cfg.n_layers % cfg.attn_every
    return n_super, tail


def _vlm_shape(cfg):
    per = cfg.cross_every
    n_super = cfg.n_layers // (per + 1)
    assert n_super * (per + 1) == cfg.n_layers, "vlm layout must tile"
    return n_super, per


# -------------------------------------------------------------- init_model

def init_model(key, cfg: ArchConfig) -> dict:
    dtype = _pdt(cfg)
    ks = jax.random.split(key, 8)
    params: dict[str, Any] = {
        "embed": L.embed_init(ks[0], cfg.vocab_size, cfg.d_model, dtype=dtype),
        "final_norm": L.rmsnorm_init(cfg.d_model, dtype),
    }
    fam = cfg.family
    if fam in ("dense", "audio"):
        params["blocks"] = _stack_init(_dense_block_init, ks[1],
                                       cfg.n_layers, cfg, dtype)
    elif fam == "moe":
        n = cfg.n_layers - (1 if cfg.first_dense else 0)
        params["blocks"] = _stack_init(_moe_block_init, ks[1], n, cfg, dtype)
        if cfg.first_dense:
            params["block0"] = _dense_mlp_block_init(ks[2], cfg, dtype)
    elif fam == "ssm":
        params["blocks"] = _stack_init(_ssm_block_init, ks[1],
                                       cfg.n_layers, cfg, dtype)
    elif fam == "hybrid":
        n_super, tail = _hybrid_shape(cfg)
        flat = _stack_init(_ssm_block_init, ks[1],
                           n_super * cfg.attn_every, cfg, dtype)
        params["blocks"] = jax.tree.map(
            lambda a: a.reshape(n_super, cfg.attn_every, *a.shape[1:]), flat)
        if tail:
            params["tail"] = _stack_init(_ssm_block_init, ks[2], tail, cfg, dtype)
        params["shared"] = _dense_block_init(ks[3], cfg, dtype)
    elif fam == "vlm":
        n_super, per = _vlm_shape(cfg)
        flat = _stack_init(_dense_block_init, ks[1], n_super * per, cfg, dtype)
        params["blocks"] = jax.tree.map(
            lambda a: a.reshape(n_super, per, *a.shape[1:]), flat)
        params["cross"] = _stack_init(_cross_block_init, ks[2],
                                      n_super, cfg, dtype)
    else:
        raise ValueError(f"unknown family {fam!r}")
    return params


# -------------------------------------------------------------- init_cache

def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    dtype = _dt(cfg)
    fam = cfg.family

    def attn_cache(n=None):
        mk = (A.mla_cache_init if cfg.kv_lora_rank else A.gqa_cache_init)
        one = mk(cfg, batch, max_len, dtype)
        if n is None:
            return one
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n, *a.shape)), one)

    if fam in ("dense", "audio"):
        return {"layers": attn_cache(cfg.n_layers)}
    if fam == "moe":
        n = cfg.n_layers - (1 if cfg.first_dense else 0)
        c = {"layers": attn_cache(n)}
        if cfg.first_dense:
            c["layer0"] = attn_cache()
        return c
    if fam == "ssm":
        one = S.mamba2_state_init(cfg, batch, dtype)
        return {"layers": jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_layers, *a.shape)), one)}
    if fam == "hybrid":
        n_super, tail = _hybrid_shape(cfg)
        one = S.mamba2_state_init(cfg, batch, dtype)
        c = {"layers": jax.tree.map(
            lambda a: jnp.broadcast_to(
                a[None, None], (n_super, cfg.attn_every, *a.shape)), one),
            "shared": attn_cache(n_super)}
        if tail:
            c["tail"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (tail, *a.shape)), one)
        return c
    if fam == "vlm":
        n_super, per = _vlm_shape(cfg)
        one = attn_cache()
        return {"layers": jax.tree.map(
            lambda a: jnp.broadcast_to(a[None, None],
                                       (n_super, per, *a.shape)), one)}
    raise ValueError(fam)


# ------------------------------------------------------------ block applies

def _apply_dense_block(p, x, cfg, positions, window, cache, cache_pos):
    h, new_c = A.gqa_apply(p["attn"], L.rmsnorm(p["norm1"], x), cfg,
                           positions=positions, window=window,
                           cache=cache, cache_pos=cache_pos)
    x = x + h
    x = x + L.swiglu(p["mlp"], L.rmsnorm(p["norm2"], x))
    return x, new_c


def _apply_attn(p, x, cfg, positions, window, cache, cache_pos):
    if cfg.kv_lora_rank:
        return A.mla_apply(p, x, cfg, positions=positions, window=window,
                           cache=cache, cache_pos=cache_pos)
    return A.gqa_apply(p, x, cfg, positions=positions, window=window,
                       cache=cache, cache_pos=cache_pos)


def _apply_moe_block(p, x, cfg, positions, cache, cache_pos, mesh, dp_axes):
    h, new_c = _apply_attn(p["attn"], L.rmsnorm(p["norm1"], x), cfg,
                           positions, 0, cache, cache_pos)
    x = x + h
    y, aux = M.moe_apply(p["moe"], L.rmsnorm(p["norm2"], x), cfg,
                         mesh=mesh, dp_axes=dp_axes)
    return x + y, new_c, aux


def _apply_ssm_block(p, x, cfg, state, decode):
    h, new_s = S.mamba2_apply(p["mamba"], L.rmsnorm(p["norm"], x), cfg,
                              state=state, decode=decode)
    return x + h, new_s


def _apply_cross_block(p, x, cfg, vision):
    x = x + A.cross_attn_apply(p["xattn"], L.rmsnorm(p["norm1"], x), vision, cfg)
    x = x + jnp.tanh(p["mlp_gate"].astype(x.dtype)) \
        * L.swiglu(p["mlp"], L.rmsnorm(p["norm2"], x))
    return x


# ----------------------------------------------------------------- forward

def forward(params: dict, cfg: ArchConfig, *,
            tokens: jnp.ndarray | None = None,
            embeds: jnp.ndarray | None = None,
            positions: jnp.ndarray | None = None,
            cache: dict | None = None,
            cache_pos=None,
            vision: jnp.ndarray | None = None,
            mesh=None, dp_axes: tuple[str, ...] = (),
            decode: bool = False,
            remat: bool | None = None,
            return_hidden: bool = False):
    """Run the trunk. Either ``tokens`` (B,S) int32 or ``embeds`` (B,S,D).

    positions: (S,) absolute positions (defaults to arange(S)).
    cache: pytree from init_cache (prefill fills it, decode updates it).
    Returns (logits (B,S,V), new_cache | None, aux dict).
    """
    if embeds is None:
        x = L.embed(params["embed"], tokens, compute_dtype=_dt(cfg))
    else:
        x = embeds.astype(_dt(cfg))
    B, Sq, _ = x.shape
    if positions is None:
        positions = jnp.arange(Sq, dtype=jnp.int32)
    use_remat = cfg.remat if remat is None else remat
    _scan_l = functools.partial(_scan, use_scan=cfg.scan_layers)
    fam = cfg.family
    aux_total = jnp.zeros((), jnp.float32)

    def maybe_ckpt(f):
        return jax.checkpoint(f) if use_remat else f

    if fam in ("dense", "audio"):
        # Without a sliding-window pattern every layer is global: keep the
        # window a compile-time 0 instead of scanning a zeros array, so
        # the attention layer can take its Pallas kernel route
        # (models/attention.gqa_apply requires a static window —
        # cfg.kernel_vjp_mode, DESIGN.md §9). gemma3-style patterns scan
        # the per-layer window and stay on the XLA path.
        win_static = not cfg.sliding_window
        windows = None if win_static else jnp.asarray(layer_windows(cfg))

        def body(carry, xs):
            h = carry
            if cache is None:
                if win_static:
                    p_l, w = xs, 0
                else:
                    p_l, w = xs
                h, _ = _apply_dense_block(p_l, h, cfg, positions, w, None, None)
                return h, 0
            if win_static:
                p_l, c_l = xs
                w = 0
            else:
                p_l, w, c_l = xs
            h, new_c = _apply_dense_block(p_l, h, cfg, positions, w, c_l,
                                          cache_pos)
            return h, new_c

        if cache is None:
            xs_in = params["blocks"] if win_static \
                else (params["blocks"], windows)
            x, _ = _scan_l(maybe_ckpt(body), x, xs_in)
            new_cache = None
        else:
            xs_in = (params["blocks"], cache["layers"]) if win_static \
                else (params["blocks"], windows, cache["layers"])
            x, new_layers = _scan_l(body, x, xs_in)
            new_cache = {"layers": new_layers}

    elif fam == "moe":
        def body(carry, xs):
            h, aux = carry
            if cache is None:
                p_l = xs
                h, _, a = _apply_moe_block(p_l, h, cfg, positions, None,
                                           cache_pos, mesh, dp_axes)
                return (h, aux + a), 0
            p_l, c_l = xs
            h, new_c, a = _apply_moe_block(p_l, h, cfg, positions, c_l,
                                           cache_pos, mesh, dp_axes)
            return (h, aux + a), new_c

        new_cache = None
        c0_new = None
        if cfg.first_dense:
            c0 = None if cache is None else cache["layer0"]
            x, c0_new = _apply_dense_block(
                params["block0"], x, cfg, positions, 0, c0, cache_pos) \
                if not cfg.kv_lora_rank else _apply_mla_dense0(
                    params["block0"], x, cfg, positions, c0, cache_pos)
        if cache is None:
            (x, aux_total), _ = _scan_l(maybe_ckpt(body), (x, aux_total),
                                             params["blocks"])
        else:
            (x, aux_total), new_layers = _scan_l(
                body, (x, aux_total), (params["blocks"], cache["layers"]))
            new_cache = {"layers": new_layers}
            if cfg.first_dense:
                new_cache["layer0"] = c0_new

    elif fam == "ssm":
        def body(carry, xs):
            h = carry
            if cache is None:
                h, _ = _apply_ssm_block(xs, h, cfg, None, False)
                return h, 0
            p_l, s_l = xs
            h, new_s = _apply_ssm_block(p_l, h, cfg, s_l, decode)
            return h, new_s

        if cache is None:
            x, _ = _scan_l(maybe_ckpt(body), x, params["blocks"])
            new_cache = None
        else:
            x, new_states = _scan_l(body, x, (params["blocks"],
                                                   cache["layers"]))
            new_cache = {"layers": new_states}

    elif fam == "hybrid":
        n_super, tail = _hybrid_shape(cfg)

        def inner(carry, xs):
            h = carry
            if cache is None:
                h, _ = _apply_ssm_block(xs, h, cfg, None, False)
                return h, 0
            p_l, s_l = xs
            h, new_s = _apply_ssm_block(p_l, h, cfg, s_l, decode)
            return h, new_s

        def super_body(carry, xs):
            h = carry
            if cache is None:
                p_grp = xs
                h, _ = _scan_l(inner, h, p_grp)
                h, _ = _apply_dense_block(params["shared"], h, cfg,
                                          positions, 0, None, None)
                return h, 0
            p_grp, s_grp, ac = xs
            h, new_s = _scan_l(inner, h, (p_grp, s_grp))
            h, new_ac = _apply_dense_block(params["shared"], h, cfg,
                                           positions, 0, ac, cache_pos)
            return h, (new_s, new_ac)

        if cache is None:
            x, _ = _scan_l(maybe_ckpt(super_body), x, params["blocks"])
            if tail:
                x, _ = _scan_l(maybe_ckpt(inner), x, params["tail"])
            new_cache = None
        else:
            x, (new_s, new_ac) = _scan_l(
                super_body, x, (params["blocks"], cache["layers"],
                                cache["shared"]))
            new_cache = {"layers": new_s, "shared": new_ac}
            if tail:
                x, new_tail = _scan_l(inner, x, (params["tail"],
                                                      cache["tail"]))
                new_cache["tail"] = new_tail

    elif fam == "vlm":
        assert vision is not None, "vlm needs stubbed patch embeddings"

        def inner(carry, xs):
            h = carry
            if cache is None:
                h, _ = _apply_dense_block(xs, h, cfg, positions, 0, None, None)
                return h, 0
            p_l, c_l = xs
            h, new_c = _apply_dense_block(p_l, h, cfg, positions, 0, c_l,
                                          cache_pos)
            return h, new_c

        def super_body(carry, xs):
            h = carry
            if cache is None:
                p_grp, p_cross = xs
                h, _ = _scan_l(inner, h, p_grp)
                h = _apply_cross_block(p_cross, h, cfg, vision)
                return h, 0
            p_grp, p_cross, c_grp = xs
            h, new_c = _scan_l(inner, h, (p_grp, c_grp))
            h = _apply_cross_block(p_cross, h, cfg, vision)
            return h, new_c

        if cache is None:
            x, _ = _scan_l(maybe_ckpt(super_body), x,
                                (params["blocks"], params["cross"]))
            new_cache = None
        else:
            x, new_layers = _scan_l(super_body, x,
                                         (params["blocks"], params["cross"],
                                          cache["layers"]))
            new_cache = {"layers": new_layers}
    else:
        raise ValueError(fam)

    x = L.rmsnorm(params["final_norm"], x)
    if return_hidden:   # callers fuse their own (chunked) readout (§Perf-4)
        return x, new_cache, {"moe_aux": aux_total}
    logits = L.unembed(params["embed"], x)
    return logits, new_cache, {"moe_aux": aux_total}


def _apply_dense_block_paged(p, x, cfg, positions, pool, block_tables):
    h, new_pool = A.gqa_apply_paged(p["attn"], L.rmsnorm(p["norm1"], x), cfg,
                                    positions=positions, pool=pool,
                                    block_tables=block_tables)
    x = x + h
    x = x + L.swiglu(p["mlp"], L.rmsnorm(p["norm2"], x))
    return x, new_pool


def forward_paged(params: dict, cfg: ArchConfig, *,
                  tokens: jnp.ndarray, positions: jnp.ndarray,
                  cache: dict, block_tables: jnp.ndarray):
    """One continuous-batching decode step over the paged block-pool
    cache (launch/paging.init_paged_cache, DESIGN.md §12).

    tokens: (R, 1) int32 — each scheduler slot's incoming token;
    positions: (R,) int32 — its absolute position (== tokens already
    cached for that slot; inactive slots pass 0 and their writes land in
    the reserved null block). Mirrors ``forward``'s decode scan bodies
    exactly, with the dense-cache attention swapped for the paged
    gather; SSM blocks are untouched — their decode step is already
    per-slot O(1) state (the batch axis IS the slot axis).

    Families: dense/audio (no sliding-window pattern), ssm, hybrid.
    Returns (logits (R, 1, V), new_cache).
    """
    fam = cfg.family
    from repro.launch.paging import supports_paged
    if not supports_paged(cfg):
        raise ValueError(f"forward_paged: unsupported family {fam!r} "
                         "(moe/vlm/sliding-window serve via the "
                         "sequential dense engine mode)")
    x = L.embed(params["embed"], tokens, compute_dtype=_dt(cfg))
    _scan_l = functools.partial(_scan, use_scan=cfg.scan_layers)

    if fam in ("dense", "audio"):
        def body(h, xs):
            p_l, c_l = xs
            return _apply_dense_block_paged(p_l, h, cfg, positions, c_l,
                                            block_tables)

        x, new_layers = _scan_l(body, x, (params["blocks"], cache["layers"]))
        new_cache = {"layers": new_layers}

    elif fam == "ssm":
        def body(h, xs):
            p_l, s_l = xs
            return _apply_ssm_block(p_l, h, cfg, s_l, True)

        x, new_states = _scan_l(body, x, (params["blocks"],
                                          cache["layers"]))
        new_cache = {"layers": new_states}

    else:  # hybrid
        _, tail = _hybrid_shape(cfg)

        def inner(h, xs):
            p_l, s_l = xs
            return _apply_ssm_block(p_l, h, cfg, s_l, True)

        def super_body(h, xs):
            p_grp, s_grp, ac = xs
            h, new_s = _scan_l(inner, h, (p_grp, s_grp))
            h, new_ac = _apply_dense_block_paged(params["shared"], h, cfg,
                                                 positions, ac,
                                                 block_tables)
            return h, (new_s, new_ac)

        x, (new_s, new_ac) = _scan_l(super_body, x,
                                     (params["blocks"], cache["layers"],
                                      cache["shared"]))
        new_cache = {"layers": new_s, "shared": new_ac}
        if tail:
            x, new_tail = _scan_l(inner, x, (params["tail"], cache["tail"]))
            new_cache["tail"] = new_tail

    x = L.rmsnorm(params["final_norm"], x)
    return L.unembed(params["embed"], x), new_cache


def _apply_mla_dense0(p, x, cfg, positions, cache, cache_pos):
    h, new_c = A.mla_apply(p["attn"], L.rmsnorm(p["norm1"], x), cfg,
                           positions=positions, cache=cache,
                           cache_pos=cache_pos)
    x = x + h
    x = x + L.swiglu(p["mlp"], L.rmsnorm(p["norm2"], x))
    return x, new_c


# ------------------------------------------------------------------- loss

def loss_fn(params, cfg: ArchConfig, batch: dict, *,
            mesh=None, dp_axes: tuple[str, ...] = ()):
    """Next-token cross-entropy (+ MoE load-balance aux)."""
    logits, _, aux = forward(params, cfg, tokens=batch["tokens"],
                             vision=batch.get("vision"),
                             mesh=mesh, dp_axes=dp_axes)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], -1)[..., 0]
    mask = batch.get("mask")
    if mask is None:
        loss = jnp.mean(nll)
    else:
        loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    total = loss + cfg.router_aux_coef * aux["moe_aux"]
    return total, {"ce": loss, "moe_aux": aux["moe_aux"]}
