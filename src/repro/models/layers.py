"""Core neural-net layers, pure JAX (no flax/haiku).

Convention: every layer is a pair of pure functions
  ``<name>_init(key, ...) -> params``   (params = pytree of jnp arrays)
  ``<name>(params, x, ...) -> y``
Parameters are kept in the dtype given at init (``param_dtype``); compute
happens in the dtype of the activations flowing in.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

Params = Any  # nested dict of arrays


# ---------------------------------------------------------------- linear ---

def linear_init(key, d_in: int, d_out: int, *, bias: bool = False,
                scale: float | None = None, dtype=jnp.float32) -> Params:
    if scale is None:
        scale = 1.0 / math.sqrt(d_in)
    w = (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)
    p = {"w": w}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


# ----------------------------------------------------------------- norms ---

def rmsnorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * p["scale"].astype(x.dtype)


def layernorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y.astype(x.dtype) * p["scale"].astype(x.dtype)
            + p["bias"].astype(x.dtype))


# ------------------------------------------------------------- embedding ---

def embed_init(key, vocab: int, d: int, *, dtype=jnp.float32) -> Params:
    return {"table": (jax.random.normal(key, (vocab, d), jnp.float32)
                      * (1.0 / math.sqrt(d))).astype(dtype)}


def embed(p: Params, ids: jnp.ndarray, compute_dtype=None) -> jnp.ndarray:
    t = p["table"]
    if compute_dtype is not None:
        t = t.astype(compute_dtype)
    return jnp.take(t, ids, axis=0)


def unembed(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    """Tied-weights readout: (..., d) @ (d, vocab)."""
    return x @ p["table"].astype(x.dtype).T


# ------------------------------------------------------------------ RoPE ---

def rope_cos_sin(positions: jnp.ndarray, head_dim: int,
                 theta: float = 10000.0) -> tuple[jnp.ndarray, jnp.ndarray]:
    """positions: (...,) int32 -> cos,sin of shape (..., head_dim//2)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, H, Dh); cos/sin: (B, S, Dh//2) or (S, Dh//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == x.ndim - 2:          # (S, half) -> broadcast over B, H
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    elif cos.ndim == x.ndim - 1:        # (B, S, half)
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    xf = x.astype(jnp.float32)
    x1f, x2f = xf[..., :half], xf[..., half:]
    out = jnp.concatenate([x1f * cos - x2f * sin, x2f * cos + x1f * sin], -1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------ MLPs ---

def swiglu_init(key, d: int, d_ff: int, *, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"gate": linear_init(k1, d, d_ff, dtype=dtype),
            "up": linear_init(k2, d, d_ff, dtype=dtype),
            "down": linear_init(k3, d_ff, d, dtype=dtype)}


def swiglu(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    return linear(p["down"], jax.nn.silu(linear(p["gate"], x)) * linear(p["up"], x))


def gelu_mlp_init(key, d: int, d_ff: int, *, dtype=jnp.float32) -> Params:
    k1, k2 = jax.random.split(key)
    return {"up": linear_init(k1, d, d_ff, bias=True, dtype=dtype),
            "down": linear_init(k2, d_ff, d, bias=True, dtype=dtype)}


def gelu_mlp(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    return linear(p["down"], jax.nn.gelu(linear(p["up"], x)))


# ----------------------------------------------------------- conv (CNNs) ---

def conv_init(key, c_in: int, c_out: int, ksize: int, *,
              dtype=jnp.float32) -> Params:
    fan_in = c_in * ksize * ksize
    w = jax.random.normal(key, (ksize, ksize, c_in, c_out), jnp.float32)
    return {"w": (w * math.sqrt(2.0 / fan_in)).astype(dtype)}


def conv2d(p: Params, x: jnp.ndarray, *, stride: int = 1,
           padding: str = "SAME") -> jnp.ndarray:
    """x: (B, H, W, C)."""
    return jax.lax.conv_general_dilated(
        x, p["w"].astype(x.dtype), (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def batchnorm_init(c: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((c,), dtype), "bias": jnp.zeros((c,), dtype),
            "mean": jnp.zeros((c,), jnp.float32),
            "var": jnp.ones((c,), jnp.float32)}


def masked_batch_moments(x: jnp.ndarray, sample_mask: jnp.ndarray):
    """Per-channel (mean, var) of x over all non-channel axes, counting
    only rows where sample_mask (shape (B,), bool) is True. With an
    all-True mask this equals jnp.mean/var over the same axes; with a
    partial mask it equals the moments of the valid sub-batch — what a
    padded ragged minibatch needs to match its unpadded reference."""
    axes = tuple(range(x.ndim - 1))
    xf = x.astype(jnp.float32)
    w = sample_mask.astype(jnp.float32).reshape(
        (-1,) + (1,) * (x.ndim - 1))
    count = jnp.maximum(jnp.sum(w) * math.prod(x.shape[1:-1]), 1.0)
    mu = jnp.sum(xf * w, axes) / count
    var = jnp.sum(jnp.square(xf - mu) * w, axes) / count
    return mu, var


def batchnorm(p: Params, x: jnp.ndarray, *, train: bool,
              momentum: float = 0.9, eps: float = 1e-5,
              sample_mask: jnp.ndarray | None = None):
    """Returns (y, new_stats). In train mode uses batch stats and returns
    updated running stats; in eval mode uses running stats. sample_mask
    (train mode only, shape (B,)) restricts the batch statistics to valid
    rows so padded samples neither shift the normalization nor leak into
    the running stats (the grouped ragged-shard path)."""
    if train:
        if sample_mask is None:
            axes = tuple(range(x.ndim - 1))
            mu = jnp.mean(x.astype(jnp.float32), axes)
            var = jnp.var(x.astype(jnp.float32), axes)
        else:
            mu, var = masked_batch_moments(x, sample_mask)
        new = {"mean": momentum * p["mean"] + (1 - momentum) * mu,
               "var": momentum * p["var"] + (1 - momentum) * var}
    else:
        mu, var = p["mean"], p["var"]
        new = {"mean": p["mean"], "var": p["var"]}
    y = (x.astype(jnp.float32) - mu) * jax.lax.rsqrt(var + eps)
    y = y.astype(x.dtype) * p["scale"].astype(x.dtype) + p["bias"].astype(x.dtype)
    return y, new
