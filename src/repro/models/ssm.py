"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) block, pure JAX.

The chunked SSD algorithm: within chunks the sequence mixing is a masked
(quadratic) matmul — MXU-friendly; across chunks a linear recurrence over
per-chunk states.  This jnp implementation doubles as the oracle for the
Pallas ``ssd_scan`` kernel in ``repro/kernels``.

Sharding note: the canonical fused ``in_proj`` interleaves head-shardable
sections (z, x, dt) with replicated ones (B, C groups), which no single
PartitionSpec can express — so projections are kept *split* (in_z, in_x,
in_bc, in_dt + split convs), letting the launch-layer shard z/x/dt over
the ``model`` axis (head parallelism) while B/C stay replicated.

Shapes follow the paper: heads H = d_inner / P (P = head dim), state N,
B/C projections shared across ``n_groups`` groups (G).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.configs.backend import arch_policy
from repro.configs.base import ArchConfig


def mamba2_init(key, cfg: ArchConfig, *, dtype) -> dict:
    d, di = cfg.d_model, cfg.d_inner
    g, n, h = cfg.ssm_n_groups, cfg.ssm_state, cfg.n_ssm_heads
    ks = jax.random.split(key, 6)
    return {
        "in_z": L.linear_init(ks[0], d, di, dtype=dtype),
        "in_x": L.linear_init(ks[1], d, di, dtype=dtype),
        "in_bc": L.linear_init(ks[2], d, 2 * g * n, dtype=dtype),
        "in_dt": L.linear_init(ks[3], d, h, dtype=dtype),
        "conv_x": {"w": (jax.random.normal(ks[4], (cfg.ssm_conv, di))
                         * 0.1).astype(dtype),
                   "b": jnp.zeros((di,), dtype)},
        "conv_bc": {"w": (jax.random.normal(ks[5], (cfg.ssm_conv, 2 * g * n))
                          * 0.1).astype(dtype),
                    "b": jnp.zeros((2 * g * n,), dtype)},
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm": L.rmsnorm_init(di, dtype),
        "out_proj": L.linear_init(ks[2], di, d, dtype=dtype),
    }


def mamba2_state_init(cfg: ArchConfig, batch: int, dtype) -> dict:
    h, p, n = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    return {"ssm": jnp.zeros((batch, h, p, n), jnp.float32),
            "conv_x": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
            "conv_bc": jnp.zeros((batch, cfg.ssm_conv - 1,
                                  2 * cfg.ssm_n_groups * cfg.ssm_state), dtype)}


def _causal_conv(x, w, b, pad=None):
    """Depthwise causal conv. x:(B,S,C), w:(K,C). pad: (B,K-1,C) history or
    None (zero pad). Returns (y, new_pad)."""
    K = w.shape[0]
    S = x.shape[1]
    if pad is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([pad.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + S, :] * w[i][None, None, :] for i in range(K))
    return y + b[None, None, :], xp[:, -(K - 1):, :]


def segsum(a):
    """Stable 'segment sum': out[..., i, j] = sum_{j<k<=i} a[..., k], -inf j>i."""
    T = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, a, b, c, *, chunk: int, initial_state=None):
    """SSD forward (pure jnp; also the model-level reference for the Pallas
    kernel). x:(B,S,H,P) dt:(B,S,H) a:(H,) b/c:(B,S,G,N).
    Returns (y: (B,S,H,P), final_state: (B,H,P,N))."""
    B, S, H, Pd = x.shape
    G, N = b.shape[2], b.shape[3]
    assert S % chunk == 0, (S, chunk)
    nc, cl = S // chunk, chunk
    rep = H // G

    xb = x.reshape(B, nc, cl, H, Pd).astype(jnp.float32)
    dtb = dt.reshape(B, nc, cl, H).astype(jnp.float32)
    bb = jnp.repeat(b.reshape(B, nc, cl, G, N), rep, axis=3).astype(jnp.float32)
    cb = jnp.repeat(c.reshape(B, nc, cl, G, N), rep, axis=3).astype(jnp.float32)

    da = dtb * a[None, None, None, :]                      # log-decays
    da_cs = jnp.cumsum(da, axis=2)

    # intra-chunk (quadratic, MXU-friendly)
    seg = segsum(jnp.moveaxis(da, -1, -2))                 # (B,nc,H,cl,cl)
    decay = jnp.exp(seg)
    cb_ls = jnp.einsum("bclhn,bcshn->bchls", cb, bb)
    att = cb_ls * decay * jnp.moveaxis(dtb, -1, -2)[..., None, :]
    y_diag = jnp.einsum("bchls,bcshp->bclhp", att, xb)

    # per-chunk states
    decay_to_end = jnp.exp(da_cs[:, :, -1:, :] - da_cs)
    states = jnp.einsum("bclhn,bclh,bclh,bclhp->bchpn",
                        bb, decay_to_end, dtb, xb)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(da_cs[:, :, -1, :])              # (B,nc,H)
    s0 = (jnp.zeros((B, H, Pd, N), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(s, inp):
        dec, st = inp
        return s * dec[..., None, None] + st, s

    final, prev_states = jax.lax.scan(
        step, s0, (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(states, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)          # (B,nc,H,P,N)

    in_decay = jnp.exp(da_cs)
    y_off = jnp.einsum("bclhn,bclh,bchpn->bclhp", cb, in_decay, prev_states)

    y = (y_diag + y_off).reshape(B, S, H, Pd)
    return y.astype(x.dtype), final


def mamba2_apply(p: dict, x: jnp.ndarray, cfg: ArchConfig, *,
                 state: dict | None = None, decode: bool = False):
    """Full Mamba-2 block. x:(B,S,D). Returns (y, new_state)."""
    B, S, D = x.shape
    di, g, n, h = cfg.d_inner, cfg.ssm_n_groups, cfg.ssm_state, cfg.n_ssm_heads
    pd = cfg.ssm_head_dim

    z = L.linear(p["in_z"], x)
    xi = L.linear(p["in_x"], x)
    bc = L.linear(p["in_bc"], x)
    dt_raw = L.linear(p["in_dt"], x)

    pad_x = state["conv_x"] if state is not None else None
    pad_bc = state["conv_bc"] if state is not None else None
    xi, new_conv_x = _causal_conv(xi, p["conv_x"]["w"].astype(xi.dtype),
                                  p["conv_x"]["b"].astype(xi.dtype), pad_x)
    bc, new_conv_bc = _causal_conv(bc, p["conv_bc"]["w"].astype(bc.dtype),
                                   p["conv_bc"]["b"].astype(bc.dtype), pad_bc)
    xi = jax.nn.silu(xi)
    bc = jax.nn.silu(bc)

    xs = xi.reshape(B, S, h, pd)
    bmat = bc[..., :g * n].reshape(B, S, g, n)
    cmat = bc[..., g * n:].reshape(B, S, g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])
    a = -jnp.exp(p["a_log"])                               # (H,) < 0

    if decode:
        assert state is not None and S == 1
        s = state["ssm"]
        rep = h // g
        b1 = jnp.repeat(bmat[:, 0], rep, axis=1).astype(jnp.float32)
        c1 = jnp.repeat(cmat[:, 0], rep, axis=1).astype(jnp.float32)
        dt1 = dt[:, 0]
        x1 = xs[:, 0].astype(jnp.float32)
        da = jnp.exp(dt1 * a[None, :])
        new_ssm = s * da[..., None, None] \
            + jnp.einsum("bh,bhp,bhn->bhpn", dt1, x1, b1)
        y = jnp.einsum("bhpn,bhn->bhp", new_ssm, c1)[:, None].astype(x.dtype)
    elif (pol := arch_policy(cfg)).kernel_vjp != "ref":
        # Pallas kernel route (configs.backend.arch_policy, DESIGN.md
        # §9): "fused" differentiates through the reversed-recurrence
        # custom-VJP pair; the kernel also honors initial_state (the
        # prefill→decode handoff it used to drop) and ragged S. The
        # chunk size rides on the policy (cfg.ssm_chunk as an explicit
        # override, else the registry/autotuner choice; ops clamps it
        # into S).
        from repro.kernels import ops as kops
        y, new_ssm = kops.ssd_scan(
            xs, dt, a, bmat, cmat,
            None if state is None else state["ssm"], policy=pol)
    else:
        y, new_ssm = ssd_chunked(
            xs, dt, a, bmat, cmat, chunk=min(cfg.ssm_chunk, S),
            initial_state=None if state is None else state["ssm"])

    y = y + p["d_skip"].astype(x.dtype)[None, None, :, None] * xs
    y = y.reshape(B, S, di)
    y = L.rmsnorm(p["norm"], y) * jax.nn.silu(z)           # gated norm
    out = L.linear(p["out_proj"], y)
    new_state = None
    if state is not None:
        new_state = {"ssm": new_ssm, "conv_x": new_conv_x,
                     "conv_bc": new_conv_bc}
    return out, new_state
