"""Attention variants: GQA (w/ sliding window, QKV bias, KV cache,
cross-attention) and MLA (DeepSeek-V2 multi-head latent attention).

All functions are pure; caches are explicit pytrees threaded by the caller.

Mask convention: ``window`` is an int32 (possibly traced, so one scanned
layer body can serve both local and global layers — gemma3's 5:1 pattern).
``window == 0`` means full causal attention; ``window = w`` keeps keys with
``q_pos - k_pos < w``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.configs.backend import arch_policy
from repro.configs.base import ArchConfig

NEG_INF = -2.0 ** 30


# ------------------------------------------------------------------- GQA ---

def gqa_init(key, cfg: ArchConfig, *, dtype) -> dict:
    d, h, kh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": L.linear_init(ks[0], d, h * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wk": L.linear_init(ks[1], d, kh * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wv": L.linear_init(ks[2], d, kh * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wo": L.linear_init(ks[3], h * hd, d, dtype=dtype),
    }


def gqa_cache_init(cfg: ArchConfig, batch: int, max_len: int, dtype) -> dict:
    kh, hd = cfg.n_kv_heads, cfg.head_dim
    return {"k": jnp.zeros((batch, max_len, kh, hd), dtype),
            "v": jnp.zeros((batch, max_len, kh, hd), dtype)}


def _sdpa(q, k, v, mask, scale):
    """q:(B,S,Kh,G,Dh) k/v:(B,T,Kh,Dh) mask:(B,S,T) or (S,T) -> (B,S,Kh,G,Dh)."""
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k,
                        preferred_element_type=jnp.float32) * scale
    if mask.ndim == 2:
        mask = mask[None]
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bkgst,btkd->bskgd", probs, v)


# Blockwise (flash-style) online-softmax attention in pure XLA: outer
# lax.map over query chunks, inner lax.scan over KV chunks. Peak activation
# per layer is O(bq*bk) instead of O(Sq*Sk) — at prefill_32k that removes
# the dominant HBM term of the whole framework (EXPERIMENTS.md §Perf-1).
BLOCKWISE_MIN = 4096        # use blockwise when Sq >= this and divisible
BLOCK_Q = 1024
BLOCK_KV = 1024


def _sdpa_blockwise(q, k, v, q_pos, k_pos, window, scale,
                    bq: int | None = None, bk: int | None = None):
    """Same contract as _sdpa but mask given by positions + window.

    q: (B,S,Kh,G,Dh); k: (B,T,Kh,Dk); v: (B,T,Kh,Dv) (Dk may differ from
    Dv — MLA). q_pos: (S,), k_pos: (T,), window: int32 scalar (0 = full).
    """
    bq = BLOCK_Q if bq is None else bq
    bk = BLOCK_KV if bk is None else bk
    B, S, Kh, G, Dk = q.shape
    T, Dv = k.shape[1], v.shape[-1]
    nq, nk = S // bq, T // bk
    w = jnp.asarray(window, jnp.int32)

    kb = jnp.moveaxis(k.reshape(B, nk, bk, Kh, Dk), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nk, bk, Kh, Dv), 1, 0)
    kpb = k_pos.reshape(nk, bk)

    def q_chunk(args):
        qc, qpc = args                                  # (B,bq,Kh,G,Dk),(bq,)

        def kv_step(carry, inp):
            m, l, acc = carry
            k_b, v_b, kp_b = inp
            s = jnp.einsum("bqkgd,btkd->bkgqt", qc, k_b,
                           preferred_element_type=jnp.float32) * scale
            mask = (kp_b[None, :] <= qpc[:, None]) \
                & ((qpc[:, None] - kp_b[None, :] < w) | (w == 0))
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqt,btkd->bkgqd", p.astype(v_b.dtype), v_b,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Kh, G, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Kh, G, bq), jnp.float32)
        a0 = jnp.zeros((B, Kh, G, bq, Dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (kb, vb, kpb))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return jnp.moveaxis(out, 3, 1)                  # (B,bq,Kh,G,Dv)

    qb = jnp.moveaxis(q.reshape(B, nq, bq, Kh, G, Dk), 1, 0)
    qpb = q_pos.reshape(nq, bq)
    out = jax.lax.map(q_chunk, (qb, qpb))               # (nq,B,bq,Kh,G,Dv)
    out = jnp.moveaxis(out, 0, 1).reshape(B, S, Kh, G, Dv)
    return out.astype(v.dtype)


def _use_blockwise(sq: int, t: int, bq=None, bk=None) -> bool:
    bq = BLOCK_Q if bq is None else bq
    bk = BLOCK_KV if bk is None else bk
    return sq >= BLOCKWISE_MIN and sq % bq == 0 and t % bk == 0


def _static_window(window):
    """``int(window)`` when the window is a compile-time constant, else
    None. The Pallas kernel path bakes the window into the kernel body, so
    a traced window (gemma3's scanned local/global layer pattern) keeps
    the XLA path."""
    try:
        return int(window)
    except (TypeError, jax.errors.ConcretizationTypeError,
            jax.errors.TracerArrayConversionError):
        return None


def gqa_apply(p: dict, x: jnp.ndarray, cfg: ArchConfig, *,
              positions: jnp.ndarray, window=0,
              cache: dict | None = None, cache_pos=None):
    """Self-attention. x:(B,S,D); positions:(S,) absolute token positions.

    Train/prefill: cache=None or a cache to fill (prefill).
    Decode: S==1, cache holds past K/V, cache_pos = scalar write index.
    Returns (y, new_cache).
    """
    B, S, D = x.shape
    h, kh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = h // kh
    q = L.linear(p["wq"], x).reshape(B, S, h, hd)
    k = L.linear(p["wk"], x).reshape(B, S, kh, hd)
    v = L.linear(p["wv"], x).reshape(B, S, kh, hd)

    cos, sin = L.rope_cos_sin(positions, hd, cfg.rope_theta)
    q = L.apply_rope(q, cos, sin)
    k = L.apply_rope(k, cos, sin)

    sw = _static_window(window)
    pol = arch_policy(cfg)
    if pol.kernel_vjp != "ref" and cache is None and sw is not None:
        # Pallas kernel route (configs.backend.arch_policy, DESIGN.md §9):
        # "fused" differentiates through the streaming custom-VJP pair —
        # the path DENSE stage-2 distillation takes when the student (or
        # the generator's teacher ensemble) is an attention LM. Diverges
        # BEFORE the positions-based mask construction below: the kernel
        # builds causal/window masks from block indices, under the
        # contract that positions are contiguous (every cache=None call
        # site passes arange(S)); traced windows and decode/prefill stay
        # on the XLA paths. Block shapes ride on the policy
        # (cfg.attn_block_q/kv as explicit overrides, else the
        # registry/autotuner choice).
        from repro.kernels import ops as kops
        out = kops.flash_attention(
            jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2),
            jnp.moveaxis(v, 1, 2), causal=True, window=sw, policy=pol)
        out = jnp.moveaxis(out, 1, 2)                    # (B, S, h, hd)
        return L.linear(p["wo"], out.reshape(B, S, h * hd).astype(x.dtype)), \
            None

    if cache is not None:
        pos = positions[0] if cache_pos is None else cache_pos
        k_all = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                             (0, pos, 0, 0))
        v_all = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                             (0, pos, 0, 0))
        new_cache = {"k": k_all, "v": v_all}
        T = k_all.shape[1]
        k_pos = jnp.arange(T)
        q_pos = positions[:, None]                       # (S,1) absolute
        mask = k_pos[None, :] <= q_pos                   # causal over cache
    else:
        new_cache = None
        k_all, v_all = k, v
        T = S
        k_pos = positions
        q_pos = positions[:, None]
        mask = k_pos[None, :] <= q_pos

    w = jnp.asarray(window, jnp.int32)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    q = q.reshape(B, S, kh, g, hd)
    if cfg.use_blockwise_attn and _use_blockwise(S, T, cfg.attn_block_q,
                                                 cfg.attn_block_kv):
        out = _sdpa_blockwise(q, k_all.astype(q.dtype),
                              v_all.astype(q.dtype), positions,
                              k_pos, w, scale, bq=min(cfg.attn_block_q, S),
                              bk=min(cfg.attn_block_kv, T))
    else:
        win_ok = (q_pos - k_pos[None, :] < w) | (w == 0)
        mask = mask & win_ok
        out = _sdpa(q, k_all.astype(q.dtype), v_all.astype(q.dtype),
                    mask, scale)
    y = L.linear(p["wo"], out.reshape(B, S, h * hd).astype(x.dtype))
    return y, new_cache


def gqa_apply_paged(p: dict, x: jnp.ndarray, cfg: ArchConfig, *,
                    positions: jnp.ndarray, pool: dict,
                    block_tables: jnp.ndarray):
    """One-token-per-request decode against a block-pool cache
    (launch/paging.py, DESIGN.md §12).

    x: (R, 1, D) — the incoming token for each scheduler slot;
    positions: (R,) int32 — that token's absolute position (== tokens
    already cached for the slot); pool: {"k","v"} of (P, page, Kh, Dh);
    block_tables: (R, M).

    The new K/V is scattered to pool row ``(block_tables[r, pos//page],
    pos % page)`` — inactive slots carry all-zero table rows, so their
    writes land in reserved null block 0 — then attention runs over each
    slot's first ``positions[r] + 1`` cached tokens through
    ops.paged_attention (policy-routed: ref oracle or Pallas kernel).
    Returns (y, new_pool).
    """
    R, S, D = x.shape
    h, kh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = L.linear(p["wq"], x).reshape(R, S, h, hd)
    k = L.linear(p["wk"], x).reshape(R, S, kh, hd)
    v = L.linear(p["wv"], x).reshape(R, S, kh, hd)

    cos, sin = L.rope_cos_sin(positions[:, None], hd, cfg.rope_theta)
    q = L.apply_rope(q, cos, sin)                    # per-request (R,1,half)
    k = L.apply_rope(k, cos, sin)

    P, page = pool["k"].shape[0], pool["k"].shape[1]
    blk = jnp.take_along_axis(block_tables,
                              (positions // page)[:, None], axis=1)[:, 0]
    flat = blk * page + positions % page             # (R,) pool row ids
    new_pool = {}
    for name, cur in (("k", k), ("v", v)):
        fp = pool[name].reshape(P * page, kh, hd)
        new_pool[name] = fp.at[flat].set(
            cur[:, 0].astype(fp.dtype)).reshape(P, page, kh, hd)

    from repro.kernels import ops as kops
    out = kops.paged_attention(q[:, 0], new_pool["k"], new_pool["v"],
                               block_tables, positions + 1,
                               policy=arch_policy(cfg))
    y = L.linear(p["wo"], out.reshape(R, 1, h * hd).astype(x.dtype))
    return y, new_pool


# ---------------------------------------------------------- cross-attention

def cross_attn_init(key, cfg: ArchConfig, *, dtype) -> dict:
    """Gated cross-attention onto a stubbed vision/audio stream
    (llama-3.2-vision style: zero-init tanh gate)."""
    d, h, kh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    src = cfg.vision_dim or d
    ks = jax.random.split(key, 5)
    return {
        "wq": L.linear_init(ks[0], d, h * hd, dtype=dtype),
        "wk": L.linear_init(ks[1], src, kh * hd, dtype=dtype),
        "wv": L.linear_init(ks[2], src, kh * hd, dtype=dtype),
        "wo": L.linear_init(ks[3], h * hd, d, dtype=dtype),
        "gate": jnp.zeros((), dtype),
    }


def cross_attn_apply(p: dict, x: jnp.ndarray, src: jnp.ndarray,
                     cfg: ArchConfig) -> jnp.ndarray:
    """x:(B,S,D) attends over src:(B,P,src_dim); no mask (full visibility)."""
    B, S, _ = x.shape
    P = src.shape[1]
    h, kh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = h // kh
    q = L.linear(p["wq"], x).reshape(B, S, kh, g, hd)
    k = L.linear(p["wk"], src.astype(x.dtype)).reshape(B, P, kh, hd)
    v = L.linear(p["wv"], src.astype(x.dtype)).reshape(B, P, kh, hd)
    mask = jnp.ones((S, P), bool)
    out = _sdpa(q, k, v, mask, 1.0 / jnp.sqrt(hd).astype(jnp.float32))
    y = L.linear(p["wo"], out.reshape(B, S, h * hd).astype(x.dtype))
    return jnp.tanh(p["gate"].astype(x.dtype)) * y


# ------------------------------------------------------------------- MLA ---

def mla_init(key, cfg: ArchConfig, *, dtype) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    qd = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    p = {}
    if cfg.q_lora_rank:
        p["wq_a"] = L.linear_init(ks[0], d, cfg.q_lora_rank, dtype=dtype)
        p["q_norm"] = L.rmsnorm_init(cfg.q_lora_rank, dtype)
        p["wq_b"] = L.linear_init(ks[1], cfg.q_lora_rank, h * qd, dtype=dtype)
    else:
        p["wq"] = L.linear_init(ks[0], d, h * qd, dtype=dtype)
    p["wkv_a"] = L.linear_init(
        ks[2], d, cfg.kv_lora_rank + cfg.qk_rope_head_dim, dtype=dtype)
    p["kv_norm"] = L.rmsnorm_init(cfg.kv_lora_rank, dtype)
    p["wkv_b"] = L.linear_init(
        ks[3], cfg.kv_lora_rank, h * (cfg.qk_nope_head_dim + cfg.v_head_dim),
        dtype=dtype)
    p["wo"] = L.linear_init(ks[4], h * cfg.v_head_dim, d, dtype=dtype)
    return p


def mla_cache_init(cfg: ArchConfig, batch: int, max_len: int, dtype) -> dict:
    """MLA caches the *compressed* latent + shared rope key — its main win."""
    return {"c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), dtype)}


def mla_apply(p: dict, x: jnp.ndarray, cfg: ArchConfig, *,
              positions: jnp.ndarray, cache: dict | None = None,
              cache_pos=None, window=0):
    B, S, D = x.shape
    h = cfg.n_heads
    nd, rd, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim

    if cfg.q_lora_rank:
        q = L.linear(p["wq_b"], L.rmsnorm(p["q_norm"], L.linear(p["wq_a"], x)))
    else:
        q = L.linear(p["wq"], x)
    q = q.reshape(B, S, h, nd + rd)
    qn, qr = q[..., :nd], q[..., nd:]
    cos, sin = L.rope_cos_sin(positions, rd, cfg.rope_theta)
    qr = L.apply_rope(qr, cos, sin)

    kv_a = L.linear(p["wkv_a"], x)
    c_kv = L.rmsnorm(p["kv_norm"], kv_a[..., :cfg.kv_lora_rank])
    k_rope = L.apply_rope(kv_a[..., None, cfg.kv_lora_rank:], cos, sin)[:, :, 0]

    if cache is not None:
        pos = positions[0] if cache_pos is None else cache_pos
        c_all = jax.lax.dynamic_update_slice(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, pos, 0))
        r_all = jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), (0, pos, 0))
        new_cache = {"c_kv": c_all, "k_rope": r_all}
        T = c_all.shape[1]
        k_pos = jnp.arange(T)
    else:
        new_cache = None
        c_all, r_all = c_kv, k_rope
        T = S
        k_pos = positions

    kv = L.linear(p["wkv_b"], c_all.astype(x.dtype)).reshape(B, T, h, nd + vd)
    kn, v = kv[..., :nd], kv[..., nd:]

    w = jnp.asarray(window, jnp.int32)
    scale = 1.0 / jnp.sqrt(jnp.float32(nd + rd))
    if cfg.use_blockwise_attn and _use_blockwise(S, T, cfg.attn_block_q,
                                                 cfg.attn_block_kv):
        q_cat = jnp.concatenate([qn, qr], -1)[:, :, :, None, :]  # G=1
        k_cat = jnp.concatenate(
            [kn, jnp.broadcast_to(r_all[:, :, None, :].astype(kn.dtype),
                                  (B, T, h, rd))], -1)
        out = _sdpa_blockwise(q_cat, k_cat, v, positions, k_pos, w, scale,
                              bq=min(cfg.attn_block_q, S),
                              bk=min(cfg.attn_block_kv, T))
        out = out[:, :, :, 0, :]                                 # (B,S,h,vd)
    else:
        q_pos = positions[:, None]
        mask = k_pos[None, :] <= q_pos
        mask = mask & ((q_pos - k_pos[None, :] < w) | (w == 0))
        scores = (jnp.einsum("bshd,bthd->bhst", qn, kn,
                             preferred_element_type=jnp.float32)
                  + jnp.einsum("bshd,btd->bhst", qr, r_all.astype(qr.dtype),
                               preferred_element_type=jnp.float32)) * scale
        scores = jnp.where(mask[None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        out = jnp.einsum("bhst,bthd->bshd", probs, v)
    y = L.linear(p["wo"], out.reshape(B, S, h * vd).astype(x.dtype))
    return y, new_cache
