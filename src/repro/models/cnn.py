"""CNN client-model zoo for the paper-faithful DENSE path.

The paper's heterogeneous-FL experiment (Table 2) uses ResNet-18, two small
CNNs, WRN-16-1 and WRN-40-1 on CIFAR10. All are provided here with a common
functional interface; every BatchNorm records (batch μ/σ², running μ/σ²) so
the DENSE generator's L_BN (Eq. 3, DeepInversion-style) can be computed.

API:
  spec = CNNSpec(kind=..., num_classes=..., width=...)
  params = cnn_init(key, spec)
  logits, new_params, bn_stats = cnn_apply(params, spec, x, train=...)
    bn_stats: list of {"mean","var","running_mean","running_var"} per BN,
    new_params: params with updated BN running stats (when train=True).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models import layers as L

KINDS = ("cnn1", "cnn2", "resnet18", "wrn16_1", "wrn40_1", "lenet")


@dataclass(frozen=True)
class CNNSpec:
    kind: str = "cnn1"
    num_classes: int = 10
    in_ch: int = 3
    width: float = 1.0          # channel multiplier (tests shrink it)
    image_size: int = 32

    def ch(self, c: int) -> int:
        return max(4, int(round(c * self.width)))


# ------------------------------------------------------------ primitives --

def _cbr_init(key, c_in, c_out, ksize=3):
    return {"conv": L.conv_init(key, c_in, c_out, ksize),
            "bn": L.batchnorm_init(c_out)}


def _cbr(p, x, stats, train, stride=1, relu=True, sample_mask=None):
    pre = L.conv2d(p["conv"], x, stride=stride)
    if sample_mask is None:
        axes = tuple(range(pre.ndim - 1))
        mu = jnp.mean(pre.astype(jnp.float32), axes)
        var = jnp.var(pre.astype(jnp.float32), axes)
    else:
        mu, var = L.masked_batch_moments(pre, sample_mask)
    stats.append({"mean": mu, "var": var,
                  "running_mean": p["bn"]["mean"],
                  "running_var": p["bn"]["var"]})
    y, upd = L.batchnorm(p["bn"], pre, train=train,
                         sample_mask=sample_mask if train else None)
    new_p = {"conv": p["conv"], "bn": {**p["bn"], **upd}}
    return (jax.nn.relu(y) if relu else y), new_p


# ------------------------------------------------------------- small CNNs --

def _cnn_stack_init(key, spec: CNNSpec, chans):
    ks = jax.random.split(key, len(chans) + 1)
    layers = []
    c_prev = spec.in_ch
    for i, c in enumerate(chans):
        layers.append(_cbr_init(ks[i], c_prev, spec.ch(c)))
        c_prev = spec.ch(c)
    feat = max(1, spec.image_size // (2 ** len(chans)))
    fc = L.linear_init(ks[-1], c_prev * feat * feat, spec.num_classes, bias=True)
    return {"layers": layers, "fc": fc}


def _cnn_stack_apply(p, spec, x, train, sample_mask=None):
    stats, new_layers = [], []
    for lp in p["layers"]:
        x, np_ = _cbr(lp, x, stats, train, sample_mask=sample_mask)
        new_layers.append(np_)
        if x.shape[1] > 1:           # stop pooling at 1x1 (tiny test images)
            x = _maxpool2(x)         # strided maximums: ~4x less bandwidth
                                     # than reduce_window on XLA CPU
    x = x.reshape(x.shape[0], -1)
    logits = L.linear(p["fc"], x)
    return logits, {"layers": new_layers, "fc": p["fc"]}, stats


# ------------------------------------------- grouped (m-client) fast path --
#
# Eval-mode forward of m same-spec conv-stack clients as ONE fused network.
# Two static regimes, picked from the (trace-time) batch size:
#
#   * small batch (B < _GROUPED_IM2COL_MAX_B): im2col — every conv becomes
#     patch extraction (9 shifted slices) + one client-batched einsum, so
#     the whole ensemble layer is a single wide GEMM. At small B the
#     per-conv fixed costs dominate the unrolled loop and this is ~2x
#     faster on CPU.
#   * large batch: layer 1 is a single conv with client-concatenated
#     output channels (the input is shared, nothing is duplicated), then
#     lax.map over the client axis runs the remaining layers as one
#     compiled body executed m times. At large B all formulations are
#     conv-FLOP-bound; this one never hits XLA-CPU's slow
#     feature_group_count path and keeps memory O(1) in m.
#
# Both match the unrolled per-client forward to float tolerance.

_GROUPED_IM2COL_MAX_B = 32


def _grouped_kernel(w: jnp.ndarray) -> jnp.ndarray:
    """(m, k, k, c_in, c_out) stacked client kernels -> one
    (k, k, c_in, m*c_out) kernel with client-major output channels."""
    m, k1, k2, ci, co = w.shape
    return jnp.transpose(w, (1, 2, 3, 0, 4)).reshape(k1, k2, ci, m * co)


def _bn_eval(bn, pre32, compute_dtype):
    """layers.batchnorm(train=False) on broadcast-ready stat shapes."""
    y = (pre32 - bn["mean"]) * jax.lax.rsqrt(bn["var"] + 1e-5)
    return y.astype(compute_dtype) * bn["scale"].astype(compute_dtype) \
        + bn["bias"].astype(compute_dtype)


def _fold_bn(w: jnp.ndarray, bn) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fold eval-mode BN into the conv: conv(x, w') + t == BN(conv(x, w)).
    Works on stacked ((m,k,k,ci,co), (m,co)) and per-client
    ((k,k,ci,co), (co,)) params. Legal only when the caller does not
    need the pre-BN batch stats."""
    s = bn["scale"] * jax.lax.rsqrt(bn["var"] + 1e-5)
    t = bn["bias"] - bn["mean"] * s
    return w * s[..., None, None, None, :], t


def _maxpool2(h: jnp.ndarray) -> jnp.ndarray:
    """2x2/stride-2 VALID max pool as 3 fused strided maximums —
    reduce_window lowers poorly on XLA CPU (~4x the bandwidth cost)."""
    hh, ww = h.shape[-3] // 2 * 2, h.shape[-2] // 2 * 2
    h = h[..., :hh, :ww, :]
    return jnp.maximum(
        jnp.maximum(h[..., 0::2, 0::2, :], h[..., 0::2, 1::2, :]),
        jnp.maximum(h[..., 1::2, 0::2, :], h[..., 1::2, 1::2, :]))


def _conv3_im2col(h: jnp.ndarray, w: jnp.ndarray, m: int) -> jnp.ndarray:
    """3x3 SAME conv of m per-client kernels as im2col batched GEMMs.

    h: (B,H,W,Ci) shared input, or (m,B,H,W,Ci) per-client.
    w: (m, 3, 3, Ci, Co). -> (m, B, H, W, Co).

    Narrow input (first layer, Ci=3): materialize the full 9Ci patch
    tensor (tiny) and do ONE einsum with K=9Ci — three K=3Ci GEMMs would
    be too thin and pay 3 accumulation passes over the largest output.
    Wide input: full 9Ci patches are memory-bound, so pad once,
    concatenate only the 3 dx-shifts (3Ci) and accumulate 3 GEMMs over
    dy — 3x less copied volume at a still-wide K."""
    hh, ww = h.shape[-3], h.shape[-2]
    pad = [(0, 0)] * (h.ndim - 3) + [(1, 1), (1, 1), (0, 0)]
    hp = jnp.pad(h, pad)
    eq = "bhwf,mfo->mbhwo" if h.ndim == 4 else "mbhwf,mfo->mbhwo"
    if h.shape[-1] < 16:
        patches = jnp.concatenate(
            [hp[..., dy:dy + hh, dx:dx + ww, :]
             for dy in range(3) for dx in range(3)], axis=-1)
        return jnp.einsum(eq, patches,
                          w.reshape(m, -1, w.shape[-1]).astype(h.dtype))
    rows = jnp.concatenate([hp[..., :, dx:dx + ww, :] for dx in range(3)],
                           axis=-1)                    # (..., H+2, W, 3Ci)
    out = None
    for dy in range(3):
        wf = w[:, dy].reshape(m, -1, w.shape[-1]).astype(h.dtype)
        part = jnp.einsum(eq, rows[..., dy:dy + hh, :, :], wf)
        out = part if out is None else out + part
    return out


def _conv_im2col(h: jnp.ndarray, w: jnp.ndarray, m: int,
                 stride: int = 1) -> jnp.ndarray:
    """k x k SAME conv (k in {1, 3}), any stride, as one im2col batched
    GEMM — the strided generalization of ``_conv3_im2col`` the grouped
    ResNet/WRN path needs (downsampling 3x3 blocks and 1x1 projections).

    XLA's SAME convention is reproduced exactly: out = ceil(in/stride),
    pad_total = max((out-1)*stride + k - in, 0), pad_lo = pad_total // 2
    — NOT a stride-1 SAME conv subsampled afterwards, whose window
    offsets differ for even inputs. Each kernel offset (dy, dx) then
    contributes the strided slice hp[dy : dy+(out-1)*stride+1 : stride].
    """
    if w.shape[1] == 3 and stride == 1:
        return _conv3_im2col(h, w, m)
    k = w.shape[1]
    hh, ww = h.shape[-3], h.shape[-2]
    oh, ow = -(-hh // stride), -(-ww // stride)
    pt_h = max((oh - 1) * stride + k - hh, 0)
    pt_w = max((ow - 1) * stride + k - ww, 0)
    pad = [(0, 0)] * (h.ndim - 3) + [(pt_h // 2, pt_h - pt_h // 2),
                                     (pt_w // 2, pt_w - pt_w // 2), (0, 0)]
    hp = jnp.pad(h, pad)
    patches = jnp.concatenate(
        [hp[..., dy:dy + (oh - 1) * stride + 1:stride,
            dx:dx + (ow - 1) * stride + 1:stride, :]
         for dy in range(k) for dx in range(k)], axis=-1)
    eq = "bhwf,mfo->mbhwo" if h.ndim == 4 else "mbhwf,mfo->mbhwo"
    return jnp.einsum(eq, patches,
                      w.reshape(m, -1, w.shape[-1]).astype(h.dtype))


def _grouped_im2col(stacked, x, m, with_stats):
    stats = []
    h = x
    for lp in stacked["layers"]:
        if with_stats:
            pre32 = _conv3_im2col(h, lp["conv"]["w"], m).astype(jnp.float32)
            stats.append({"mean": jnp.mean(pre32, (1, 2, 3)),
                          "var": jnp.var(pre32, (1, 2, 3)),
                          "running_mean": lp["bn"]["mean"],
                          "running_var": lp["bn"]["var"]})
            bn_b = jax.tree.map(lambda a: a[:, None, None, None, :],
                                lp["bn"])
            h = jax.nn.relu(_bn_eval(bn_b, pre32, x.dtype))
        else:
            wf, t = _fold_bn(lp["conv"]["w"], lp["bn"])
            pre = _conv3_im2col(h, wf, m)
            h = jax.nn.relu(pre + t[:, None, None, None, :].astype(pre.dtype))
        if h.shape[2] > 1:           # stop pooling at 1x1 (tiny test images)
            h = _maxpool2(h)
    feat = h.reshape(m, h.shape[1], -1)
    logits = jnp.einsum("mbf,mfk->mbk", feat,
                        stacked["fc"]["w"].astype(feat.dtype))
    return logits + stacked["fc"]["b"][:, None, :].astype(logits.dtype), stats


def _grouped_conv_scan(stacked, x, m, with_stats):
    # layer 1: shared input -> one conv, client-concatenated out channels
    l1 = stacked["layers"][0]
    if with_stats:
        w1 = l1["conv"]["w"]
    else:
        w1, t1 = _fold_bn(l1["conv"]["w"], l1["bn"])
    pre = jax.lax.conv_general_dilated(
        x, _grouped_kernel(w1).astype(x.dtype), (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    l1_stats = None
    if with_stats:
        pre32 = pre.astype(jnp.float32)
        axes = tuple(range(pre.ndim - 1))
        l1_stats = {"mean": jnp.mean(pre32, axes).reshape(m, -1),
                    "var": jnp.var(pre32, axes).reshape(m, -1),
                    "running_mean": l1["bn"]["mean"],
                    "running_var": l1["bn"]["var"]}
        bn_flat = jax.tree.map(lambda a: a.reshape(-1), l1["bn"])
        h = jax.nn.relu(_bn_eval(bn_flat, pre32, x.dtype))
    else:
        h = jax.nn.relu(pre + t1.reshape(-1).astype(pre.dtype))
    if h.shape[1] > 1:
        h = _maxpool2(h)
    b, hh, ww, mc = h.shape
    h = jnp.transpose(h.reshape(b, hh, ww, m, mc // m),
                      (3, 0, 1, 2, 4))                        # (m,B,H,W,C)

    def one(args):
        hi, layers, fc = args
        st_i = []
        for lp in layers:
            if with_stats:
                w_i = lp["conv"]["w"]
            else:
                w_i, t_i = _fold_bn(lp["conv"]["w"], lp["bn"])
            pre_i = jax.lax.conv_general_dilated(
                hi, w_i.astype(hi.dtype), (1, 1), "SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
            if with_stats:
                p32 = pre_i.astype(jnp.float32)
                ax = tuple(range(p32.ndim - 1))
                st_i.append({"mean": jnp.mean(p32, ax),
                             "var": jnp.var(p32, ax),
                             "running_mean": lp["bn"]["mean"],
                             "running_var": lp["bn"]["var"]})
                hi = jax.nn.relu(_bn_eval(lp["bn"], p32, hi.dtype))
            else:
                hi = jax.nn.relu(pre_i + t_i.astype(pre_i.dtype))
            if hi.shape[1] > 1:
                hi = _maxpool2(hi)
        lg = hi.reshape(hi.shape[0], -1) @ fc["w"].astype(hi.dtype)
        return lg + fc["b"].astype(lg.dtype), st_i

    logits, rest_stats = jax.lax.map(
        one, (h, stacked["layers"][1:], stacked["fc"]))
    if not with_stats:
        return logits, []
    return logits, [l1_stats] + rest_stats


def _grouped_cbr(lp, h, m, stats, with_stats, compute_dtype, *,
                 stride=1, relu=True):
    """conv+BN(+relu) of m stacked clients, eval mode — im2col GEMM with
    either recorded batch stats (L_BN path) or BN folded into the kernel
    (``_fold_bn``, stats-free path). h: shared (B,...) or per-client
    (m, B, ...)."""
    if with_stats:
        pre32 = _conv_im2col(h, lp["conv"]["w"], m,
                             stride).astype(jnp.float32)
        stats.append({"mean": jnp.mean(pre32, (1, 2, 3)),
                      "var": jnp.var(pre32, (1, 2, 3)),
                      "running_mean": lp["bn"]["mean"],
                      "running_var": lp["bn"]["var"]})
        bn_b = jax.tree.map(lambda a: a[:, None, None, None, :], lp["bn"])
        y = _bn_eval(bn_b, pre32, compute_dtype)
    else:
        wf, t = _fold_bn(lp["conv"]["w"], lp["bn"])
        pre = _conv_im2col(h, wf, m, stride)
        y = pre + t[:, None, None, None, :].astype(pre.dtype)
    return jax.nn.relu(y) if relu else y


def _grouped_resnet(stacked, spec, x, m, with_stats):
    """Fused eval-mode forward of m same-spec ResNet/WRN clients.

    Same contract as ``_grouped_im2col``; the residual topology
    (stem -> stages of basic blocks -> global mean pool -> fc) mirrors
    ``_resnet_apply`` with each conv an ``_conv_im2col`` batched GEMM,
    and the stats list keeps ``_basic_apply``'s append order
    (c1, c2, proj) so per-client slices line up with the vmapped
    reference."""
    stats = []
    h = _grouped_cbr(stacked["stem"], x, m, stats, with_stats, x.dtype)
    for s, blocks in enumerate(stacked["stages"]):
        for b, bp in enumerate(blocks):
            stride = 2 if (b == 0 and s > 0) else 1
            y = _grouped_cbr(bp["c1"], h, m, stats, with_stats, x.dtype,
                             stride=stride)
            y = _grouped_cbr(bp["c2"], y, m, stats, with_stats, x.dtype,
                             relu=False)
            if "proj" in bp:
                sc = _grouped_cbr(bp["proj"], h, m, stats, with_stats,
                                  x.dtype, stride=stride, relu=False)
            else:
                sc = h
            h = jax.nn.relu(y + sc)
    feat = jnp.mean(h, axis=(2, 3))
    logits = jnp.einsum("mbf,mfk->mbk", feat,
                        stacked["fc"]["w"].astype(feat.dtype))
    return logits + stacked["fc"]["b"][:, None, :].astype(logits.dtype), stats


def cnn_stack_apply_grouped(stacked: dict, spec: CNNSpec, x: jnp.ndarray,
                            m: int, *, with_stats: bool = False):
    """Fused eval-mode forward of m same-spec clients.

    stacked: pytree of client params with a leading client axis
    (ensemble.stack_grouped). Returns (logits (m, B, K), bn_stats) with
    stats leaves carrying the leading client dim — the same contract as
    vmapping cnn_apply; stats is [] when with_stats=False, which also
    lets the forward fold eval-mode BN into the conv kernels (_fold_bn).
    Valid for every kind in _CNN_LAYOUT (conv-stack regimes picked by
    batch size) and _RESNET_LAYOUT (``_grouped_resnet``) —
    ``is_groupable``.
    """
    if spec.kind in _RESNET_LAYOUT:
        return _grouped_resnet(stacked, spec, x, m, with_stats)
    assert spec.kind in _CNN_LAYOUT, spec.kind
    if x.shape[0] < _GROUPED_IM2COL_MAX_B:
        return _grouped_im2col(stacked, x, m, with_stats)
    return _grouped_conv_scan(stacked, x, m, with_stats)


def is_conv_stack(kind: str) -> bool:
    """True for kinds the TRAIN-mode fused path (cnn_stack_train_grouped)
    supports — the plain conv-stack zoo."""
    return kind in _CNN_LAYOUT


def is_groupable(kind: str) -> bool:
    """True for kinds cnn_stack_apply_grouped can fuse in EVAL mode:
    the conv-stack zoo plus the ResNet/WRN kinds."""
    return kind in _CNN_LAYOUT or kind in _RESNET_LAYOUT


def _masked_moments_grouped(pre32: jnp.ndarray, sample_mask):
    """Per-client per-channel (mean, var) of (m, B, H, W, C) activations;
    sample_mask (m, B) restricts to valid rows (None = all valid)."""
    if sample_mask is None:
        return jnp.mean(pre32, (1, 2, 3)), jnp.var(pre32, (1, 2, 3))
    w = sample_mask.astype(jnp.float32)[:, :, None, None, None]
    cnt = jnp.maximum(jnp.sum(w, (1, 2, 3, 4))
                      * (pre32.shape[2] * pre32.shape[3]), 1.0)[:, None]
    mu = jnp.sum(pre32 * w, (1, 2, 3)) / cnt
    var = jnp.sum(jnp.square(pre32 - mu[:, None, None, None, :]) * w,
                  (1, 2, 3)) / cnt
    return mu, var


def cnn_stack_train_grouped(stacked: dict, spec: CNNSpec, x: jnp.ndarray,
                            sample_mask: jnp.ndarray | None = None,
                            momentum: float = 0.9, eps: float = 1e-5):
    """TRAIN-mode forward of m same-spec conv-stack clients as one fused
    network — the local-update analogue of ``cnn_stack_apply_grouped``.

    x: (m, B, H, W, C) per-client batches (unlike eval, nothing is
    shared); sample_mask: (m, B) validity of padded rows. Every conv is
    the im2col batched GEMM (``_conv3_im2col``), deliberately for train:
    the einsum's BACKWARD is again einsums (GEMMs), where both a vmapped
    and a client-concatenated conv formulation lower their kernel
    gradients to XLA CPU's pathological grouped-convolution path (the
    c benchmark table measures the gap). BN batch statistics are masked
    per client and running stats updated exactly as
    ``layers.batchnorm(train=True)`` does, so per-client results match
    ``cnn_apply(..., train=True, sample_mask=...)`` to float tolerance.

    Returns (logits (m, B, K), new_stacked, bn_stats) with stats leaves
    carrying the leading client dim — the same contract as vmapping
    ``cnn_apply``.
    """
    assert spec.kind in _CNN_LAYOUT, spec.kind
    m = x.shape[0]
    h, stats, new_layers = x, [], []
    for lp in stacked["layers"]:
        pre32 = _conv3_im2col(h, lp["conv"]["w"], m).astype(jnp.float32)
        mu, var = _masked_moments_grouped(pre32, sample_mask)
        bn = lp["bn"]
        stats.append({"mean": mu, "var": var,
                      "running_mean": bn["mean"], "running_var": bn["var"]})
        bn_b = {"mean": mu[:, None, None, None, :],
                "var": var[:, None, None, None, :],
                "scale": bn["scale"][:, None, None, None, :],
                "bias": bn["bias"][:, None, None, None, :]}
        y = (pre32 - bn_b["mean"]) * jax.lax.rsqrt(bn_b["var"] + eps)
        y = y.astype(x.dtype) * bn_b["scale"].astype(x.dtype) \
            + bn_b["bias"].astype(x.dtype)
        h = jax.nn.relu(y)
        new_layers.append({"conv": lp["conv"], "bn": {
            **bn, "mean": momentum * bn["mean"] + (1 - momentum) * mu,
            "var": momentum * bn["var"] + (1 - momentum) * var}})
        if h.shape[2] > 1:           # stop pooling at 1x1 (tiny test images)
            h = _maxpool2(h)
    feat = h.reshape(m, h.shape[1], -1)
    logits = jnp.einsum("mbf,mfk->mbk", feat,
                        stacked["fc"]["w"].astype(feat.dtype)) \
        + stacked["fc"]["b"][:, None, :].astype(feat.dtype)
    return logits, {"layers": new_layers, "fc": stacked["fc"]}, stats


# --------------------------------------------------------------- ResNet ----

def _basic_init(key, c_in, c_out, stride):
    ks = jax.random.split(key, 3)
    p = {"c1": _cbr_init(ks[0], c_in, c_out),
         "c2": _cbr_init(ks[1], c_out, c_out)}
    if stride != 1 or c_in != c_out:
        p["proj"] = _cbr_init(ks[2], c_in, c_out, ksize=1)
    return p


def _basic_apply(p, x, stats, train, stride, sample_mask=None):
    y, n1 = _cbr(p["c1"], x, stats, train, stride=stride,
                 sample_mask=sample_mask)
    y, n2 = _cbr(p["c2"], y, stats, train, relu=False,
                 sample_mask=sample_mask)
    new = {"c1": n1, "c2": n2}
    if "proj" in p:
        sc, np_ = _cbr(p["proj"], x, stats, train, stride=stride, relu=False,
                       sample_mask=sample_mask)
        new["proj"] = np_
    else:
        sc = x
    return jax.nn.relu(y + sc), new


def _resnet_init(key, spec: CNNSpec, blocks_per_stage, widths):
    ks = jax.random.split(key, 2 + len(widths) * max(blocks_per_stage))
    i = 0
    p = {"stem": _cbr_init(ks[i], spec.in_ch, spec.ch(widths[0]))}
    i += 1
    stages = []
    c_prev = spec.ch(widths[0])
    for s, w in enumerate(widths):
        blocks = []
        for b in range(blocks_per_stage[s]):
            stride = 2 if (b == 0 and s > 0) else 1
            blocks.append(_basic_init(ks[i], c_prev, spec.ch(w), stride))
            c_prev = spec.ch(w)
            i += 1
        stages.append(blocks)
    p["stages"] = stages
    p["fc"] = L.linear_init(ks[-1], c_prev, spec.num_classes, bias=True)
    return p


def _resnet_apply(p, spec, x, train, blocks_per_stage, sample_mask=None):
    stats = []
    x, new_stem = _cbr(p["stem"], x, stats, train, sample_mask=sample_mask)
    new_stages = []
    for s, blocks in enumerate(p["stages"]):
        new_blocks = []
        for b, bp in enumerate(blocks):
            stride = 2 if (b == 0 and s > 0) else 1
            x, nb = _basic_apply(bp, x, stats, train, stride,
                                 sample_mask=sample_mask)
            new_blocks.append(nb)
        new_stages.append(new_blocks)
    x = jnp.mean(x, axis=(1, 2))
    logits = L.linear(p["fc"], x)
    return logits, {"stem": new_stem, "stages": new_stages, "fc": p["fc"]}, stats


# ------------------------------------------------------------------- API ---

_RESNET_LAYOUT = {
    "resnet18": ([2, 2, 2, 2], [64, 128, 256, 512]),
    "wrn16_1": ([2, 2, 2], [16, 32, 64]),
    "wrn40_1": ([6, 6, 6], [16, 32, 64]),
}
_CNN_LAYOUT = {
    "cnn1": [32, 64, 128],
    "cnn2": [16, 32, 64, 128],
    "lenet": [6, 16],
}


def cnn_init(key, spec: CNNSpec) -> dict:
    if spec.kind in _RESNET_LAYOUT:
        bps, widths = _RESNET_LAYOUT[spec.kind]
        return _resnet_init(key, spec, bps, widths)
    if spec.kind in _CNN_LAYOUT:
        return _cnn_stack_init(key, spec, _CNN_LAYOUT[spec.kind])
    raise ValueError(f"unknown CNN kind {spec.kind!r}")


def cnn_apply(params: dict, spec: CNNSpec, x: jnp.ndarray, *, train: bool,
              sample_mask: jnp.ndarray | None = None):
    """x: (B, H, W, C) in [-1, 1]. Returns (logits, new_params, bn_stats).

    sample_mask (optional, (B,) bool): marks valid rows of a padded
    batch. Train-mode BN statistics (normalization, running-stat updates,
    and the reported bn_stats) are computed over valid rows only, so a
    padded ragged minibatch reproduces its unpadded reference exactly
    (fl/client.local_update_grouped); padded rows still produce logits —
    mask them out of the loss."""
    if spec.kind in _RESNET_LAYOUT:
        bps, _ = _RESNET_LAYOUT[spec.kind]
        return _resnet_apply(params, spec, x, train, bps,
                             sample_mask=sample_mask)
    return _cnn_stack_apply(params, spec, x, train, sample_mask=sample_mask)


def cnn_logits(params: dict, spec: CNNSpec, x: jnp.ndarray) -> jnp.ndarray:
    """Eval-mode logits only."""
    return cnn_apply(params, spec, x, train=False)[0]
