"""CNN client-model zoo for the paper-faithful DENSE path.

The paper's heterogeneous-FL experiment (Table 2) uses ResNet-18, two small
CNNs, WRN-16-1 and WRN-40-1 on CIFAR10. All are provided here with a common
functional interface; every BatchNorm records (batch μ/σ², running μ/σ²) so
the DENSE generator's L_BN (Eq. 3, DeepInversion-style) can be computed.

API:
  spec = CNNSpec(kind=..., num_classes=..., width=...)
  params = cnn_init(key, spec)
  logits, new_params, bn_stats = cnn_apply(params, spec, x, train=...)
    bn_stats: list of {"mean","var","running_mean","running_var"} per BN,
    new_params: params with updated BN running stats (when train=True).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models import layers as L

KINDS = ("cnn1", "cnn2", "resnet18", "wrn16_1", "wrn40_1", "lenet")


@dataclass(frozen=True)
class CNNSpec:
    kind: str = "cnn1"
    num_classes: int = 10
    in_ch: int = 3
    width: float = 1.0          # channel multiplier (tests shrink it)
    image_size: int = 32

    def ch(self, c: int) -> int:
        return max(4, int(round(c * self.width)))


# ------------------------------------------------------------ primitives --

def _cbr_init(key, c_in, c_out, ksize=3):
    return {"conv": L.conv_init(key, c_in, c_out, ksize),
            "bn": L.batchnorm_init(c_out)}


def _cbr(p, x, stats, train, stride=1, relu=True):
    pre = L.conv2d(p["conv"], x, stride=stride)
    axes = tuple(range(pre.ndim - 1))
    stats.append({"mean": jnp.mean(pre.astype(jnp.float32), axes),
                  "var": jnp.var(pre.astype(jnp.float32), axes),
                  "running_mean": p["bn"]["mean"],
                  "running_var": p["bn"]["var"]})
    y, upd = L.batchnorm(p["bn"], pre, train=train)
    new_p = {"conv": p["conv"], "bn": {**p["bn"], **upd}}
    return (jax.nn.relu(y) if relu else y), new_p


# ------------------------------------------------------------- small CNNs --

def _cnn_stack_init(key, spec: CNNSpec, chans):
    ks = jax.random.split(key, len(chans) + 1)
    layers = []
    c_prev = spec.in_ch
    for i, c in enumerate(chans):
        layers.append(_cbr_init(ks[i], c_prev, spec.ch(c)))
        c_prev = spec.ch(c)
    feat = max(1, spec.image_size // (2 ** len(chans)))
    fc = L.linear_init(ks[-1], c_prev * feat * feat, spec.num_classes, bias=True)
    return {"layers": layers, "fc": fc}


def _cnn_stack_apply(p, spec, x, train):
    stats, new_layers = [], []
    for lp in p["layers"]:
        x, np_ = _cbr(lp, x, stats, train)
        new_layers.append(np_)
        if x.shape[1] > 1:           # stop pooling at 1x1 (tiny test images)
            x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                      (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    x = x.reshape(x.shape[0], -1)
    logits = L.linear(p["fc"], x)
    return logits, {"layers": new_layers, "fc": p["fc"]}, stats


# --------------------------------------------------------------- ResNet ----

def _basic_init(key, c_in, c_out, stride):
    ks = jax.random.split(key, 3)
    p = {"c1": _cbr_init(ks[0], c_in, c_out),
         "c2": _cbr_init(ks[1], c_out, c_out)}
    if stride != 1 or c_in != c_out:
        p["proj"] = _cbr_init(ks[2], c_in, c_out, ksize=1)
    return p


def _basic_apply(p, x, stats, train, stride):
    y, n1 = _cbr(p["c1"], x, stats, train, stride=stride)
    y, n2 = _cbr(p["c2"], y, stats, train, relu=False)
    new = {"c1": n1, "c2": n2}
    if "proj" in p:
        sc, np_ = _cbr(p["proj"], x, stats, train, stride=stride, relu=False)
        new["proj"] = np_
    else:
        sc = x
    return jax.nn.relu(y + sc), new


def _resnet_init(key, spec: CNNSpec, blocks_per_stage, widths):
    ks = jax.random.split(key, 2 + len(widths) * max(blocks_per_stage))
    i = 0
    p = {"stem": _cbr_init(ks[i], spec.in_ch, spec.ch(widths[0]))}
    i += 1
    stages = []
    c_prev = spec.ch(widths[0])
    for s, w in enumerate(widths):
        blocks = []
        for b in range(blocks_per_stage[s]):
            stride = 2 if (b == 0 and s > 0) else 1
            blocks.append(_basic_init(ks[i], c_prev, spec.ch(w), stride))
            c_prev = spec.ch(w)
            i += 1
        stages.append(blocks)
    p["stages"] = stages
    p["fc"] = L.linear_init(ks[-1], c_prev, spec.num_classes, bias=True)
    return p


def _resnet_apply(p, spec, x, train, blocks_per_stage):
    stats = []
    x, new_stem = _cbr(p["stem"], x, stats, train)
    new_stages = []
    for s, blocks in enumerate(p["stages"]):
        new_blocks = []
        for b, bp in enumerate(blocks):
            stride = 2 if (b == 0 and s > 0) else 1
            x, nb = _basic_apply(bp, x, stats, train, stride)
            new_blocks.append(nb)
        new_stages.append(new_blocks)
    x = jnp.mean(x, axis=(1, 2))
    logits = L.linear(p["fc"], x)
    return logits, {"stem": new_stem, "stages": new_stages, "fc": p["fc"]}, stats


# ------------------------------------------------------------------- API ---

_RESNET_LAYOUT = {
    "resnet18": ([2, 2, 2, 2], [64, 128, 256, 512]),
    "wrn16_1": ([2, 2, 2], [16, 32, 64]),
    "wrn40_1": ([6, 6, 6], [16, 32, 64]),
}
_CNN_LAYOUT = {
    "cnn1": [32, 64, 128],
    "cnn2": [16, 32, 64, 128],
    "lenet": [6, 16],
}


def cnn_init(key, spec: CNNSpec) -> dict:
    if spec.kind in _RESNET_LAYOUT:
        bps, widths = _RESNET_LAYOUT[spec.kind]
        return _resnet_init(key, spec, bps, widths)
    if spec.kind in _CNN_LAYOUT:
        return _cnn_stack_init(key, spec, _CNN_LAYOUT[spec.kind])
    raise ValueError(f"unknown CNN kind {spec.kind!r}")


def cnn_apply(params: dict, spec: CNNSpec, x: jnp.ndarray, *, train: bool):
    """x: (B, H, W, C) in [-1, 1]. Returns (logits, new_params, bn_stats)."""
    if spec.kind in _RESNET_LAYOUT:
        bps, _ = _RESNET_LAYOUT[spec.kind]
        return _resnet_apply(params, spec, x, train, bps)
    return _cnn_stack_apply(params, spec, x, train)


def cnn_logits(params: dict, spec: CNNSpec, x: jnp.ndarray) -> jnp.ndarray:
    """Eval-mode logits only."""
    return cnn_apply(params, spec, x, train=False)[0]
