"""Jitted public wrappers around the Pallas kernels.

On CPU (this container) kernels run with ``interpret=True`` (Pallas
executes the kernel body in Python) — correctness-validated against the
``ref.py`` oracles; on TPU they compile to Mosaic. ``interpret`` defaults
to auto-detection of the backend.

``distill_kl`` is the repo's first custom-VJP kernel *pair*
(kernels/distill_kl.py, DESIGN.md §9): the forward streams online-LSE
accumulators, persists only the per-row statistics as residuals, and the
backward is a second Pallas kernel that re-streams the logit blocks to
emit dL/ds (and optionally dL/dt) — no (R, V) softmax intermediate in
HBM in either direction. ``with_teacher_grad=False`` skips the dL/dt
stream for stop-gradient'd teachers (DENSE's student step).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import distill_kl as _kl
from repro.kernels import ssd_scan as _ssd


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, block_q=128,
                    block_k=128, interpret=None):
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=_auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, a, b, c, *, chunk=128, interpret=None):
    return _ssd.ssd_scan(x, dt, a, b, c, chunk=chunk,
                         interpret=_auto_interpret(interpret))


# ------------------------------------------------- distill_kl (fused VJP)

def distill_kl(teacher_logits, student_logits, block_rows=256, block_v=2048,
               interpret=None, with_teacher_grad=True):
    """Per-row KL(softmax(t) ‖ softmax(s)), differentiable via the fused
    Pallas backward kernel (kernels/distill_kl.distill_kl_vjp). Any
    (R, V) shape is accepted; tail blocks are masked in-kernel."""
    return _kl.distill_kl_vjp(teacher_logits, student_logits, block_rows,
                              block_v, _auto_interpret(interpret),
                              with_teacher_grad)


def distill_kl_mean(teacher_logits, student_logits, **kw):
    """Scalar mean-KL convenience (Eq. 6 over a flattened token batch)."""
    r = distill_kl(teacher_logits, student_logits, **kw)
    return jnp.mean(r)
