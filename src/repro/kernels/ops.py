"""Public wrappers around the Pallas kernels, routed by ExecPolicy.

Execution-mode and block-shape selection live in the backend registry
(configs/backend.py, DESIGN.md §11): every wrapper takes ``policy=`` (an
``ExecPolicy``; None resolves registry defaults for the detected
backend). Interpret-mode comes from the registry too — cpu → True
(Pallas executes the kernel body in Python, correctness-validated
against the ``ref.py`` oracles), gpu/tpu → False (compiled), overridable
via ``REPRO_INTERPRET``. The old ``_auto_interpret`` helper special-cased
only tpu, so a gpu backend silently ran every kernel interpreted; the
registry route fixes that.

The old ``interpret=`` / ``block_*=`` / ``vjp_mode=`` kwargs keep
working through a deprecation shim: passing any of them emits a
``DeprecationWarning`` carrying the exact ``policy=`` replacement for
that call, and maps them onto the resolved policy as explicit
overrides. Bare legacy calls keep their historical defaults
(``vjp_mode="autodiff"`` for flash_attention/ssd_scan), so pre-registry
callers see unchanged behavior.

Removal schedule for the shim:
  * PR 8 — ``policy=`` introduced; legacy kwargs deprecated.
  * PR 9 — every in-repo caller migrated to ``policy=`` (the only
    remaining legacy calls are tests/test_backend.py's shim-equivalence
    suite, which pins the shim's behavior until removal); the warning
    now prints the exact replacement snippet.
  * PR 11 — the legacy kwargs are REMOVED: passing them becomes a
    TypeError, and the shim-equivalence tests retire with them.

Every differentiated kernel is a custom-VJP kernel *pair* (DESIGN.md §9):
the forward streams blocks with online accumulators and persists only
per-row/per-tile statistics as residuals, the backward is a second Pallas
kernel that re-streams the blocks to emit the gradients — no quadratic
softmax / state-history intermediate in HBM in either direction.

  * ``distill_kl``     — per-row online-LSE stats; the backward
    re-streams vocab blocks for dL/ds (and optionally dL/dt;
    ``with_teacher_grad=False`` skips that stream for stop-gradient'd
    teachers — DENSE's student step).
  * ``flash_attention``— per-row (m, l) softmax stats; the backward
    re-streams k-blocks (dq) and q-blocks (dk/dv, GQA group-accumulated
    in the revisited output block).
  * ``ssd_scan``       — per-chunk carried states; the backward walks
    the chunks in reverse carrying the state cotangent.

``policy.kernel_vjp`` routes flash_attention/ssd_scan (resolved from
``ArchConfig.kernel_vjp_mode`` by ``configs.backend.arch_policy``,
mirroring the distill-KL mode):

  * ``"ref"``      — the pure-jnp oracle (materialized softmax /
    sequential recurrence), differentiated by jax autodiff. The cpu
    registry default.
  * ``"autodiff"`` — the forward Pallas kernel alone. Forward-only in
    practice: jax's pallas_call JVP rule rejects ``pl.program_id``
    bodies, so differentiating this path raises — kept as the
    no-gradient serving route and as documentation of WHY the kernel
    pairs exist.
  * ``"fused"``    — the custom-VJP kernel pair (the only differentiable
    kernel path; the gpu/tpu registry default).
"""
from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp

from repro.configs import backend as B
from repro.kernels import flash_attention as _fa
from repro.kernels import distill_kl as _kl
from repro.kernels import paged_attention as _pa
from repro.kernels import ssd_scan as _ssd
from repro.kernels import ref as _ref

KERNEL_VJP_MODES = B.KERNEL_VJP_MODES
check_kernel_vjp_mode = B.check_kernel_vjp_mode


def _legacy_snippet(kernel, named, interpret, vjp_mode):
    """The exact ``policy=`` expression replacing one legacy call — the
    warning is the migration guide (see the removal schedule above)."""
    expr = "backend.resolve_exec_policy(scfg)"
    if named:
        args = ", ".join(f"{k}={v}" for k, v in named.items())
        expr += f'.override_blocks("{kernel}", {args})'
    repl = {}
    if interpret is not None:
        repl["interpret"] = bool(interpret)
    if vjp_mode is not None:
        repl["kernel_vjp"] = vjp_mode
    if repl:
        args = ", ".join(f"{k}={v!r}" for k, v in repl.items())
        expr += f".replace({args})"
    return expr


def _route(kernel, policy, legacy_blocks, interpret, vjp_mode, shape):
    """Resolve (blocks, interpret, vjp_mode) for one call.

    Pure-policy calls take everything from the registry resolution
    (autotuned blocks when enabled). Legacy kwargs emit a
    DeprecationWarning with the exact replacement snippet and overlay
    the policy: explicitly-passed blocks and interpret win; an unpassed
    legacy ``vjp_mode`` keeps the historical ``"autodiff"`` default
    (NOT the registry mode) so pre-registry call sites keep their exact
    semantics until the PR 11 removal.
    """
    legacy = interpret is not None or vjp_mode is not None \
        or any(v is not None for v in legacy_blocks.values())
    pol = B.resolve_exec_policy(policy)
    if legacy:
        named = {n: v for n, v in legacy_blocks.items() if v is not None}
        warnings.warn(
            f"{kernel}: the interpret=/vjp_mode=/block kwargs are "
            "deprecated and will be removed in PR 11 (schedule in "
            "kernels/ops.py). Replace this call with\n"
            f"    ops.{kernel}(..., policy="
            f"{_legacy_snippet(kernel, named, interpret, vjp_mode)})",
            DeprecationWarning, stacklevel=3)
        if named:
            pol = pol.override_blocks(kernel, **named)
        if interpret is not None:
            pol = pol.replace(interpret=bool(interpret))
        mode = vjp_mode if vjp_mode is not None else \
            (pol.kernel_vjp if policy is not None else "autodiff")
    else:
        mode = pol.kernel_vjp
    check_kernel_vjp_mode(mode)
    if dict(pol.overrides).get(kernel) is None and B.autotune_enabled():
        blocks = B.autotune_blocks(kernel, shape, pol)
    else:
        blocks = pol.blocks_for(kernel, shape)
    return blocks, pol.interpret, mode


def _bwd_blocks(kernel, policy, shape):
    """Backward-kernel block shapes, resolved under the SEPARATE
    ``{kernel}_bwd`` registry entry (same precedence as the forward:
    explicit override > autotuned bucket > registry default). The
    backward's traffic pattern differs from the forward's — re-streaming
    for gradient emission, often ~2x the tensor volume — so its best
    tile is tuned independently (DESIGN.md §13). ssd_scan is the
    documented exception: its residual chunk states are snapshotted at
    FORWARD chunk boundaries, so the backward must walk the identical
    chunk grid and has no entry here (configs/backend.py)."""
    name = kernel + "_bwd"
    pol = B.resolve_exec_policy(policy)
    if dict(pol.overrides).get(name) is None and B.autotune_enabled():
        return B.autotune_blocks(name, shape, pol)
    return pol.blocks_for(name, shape)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret",
                                             "vjp_mode", "bwd_q", "bwd_k"))
def _flash_impl(q, k, v, *, causal, window, block_q, block_k, interpret,
                vjp_mode, bwd_q=None, bwd_k=None):
    if vjp_mode == "ref":
        return _ref.attention(q, k, v, causal=causal, window=window)
    if vjp_mode == "fused":
        return _fa.flash_attention_vjp(q, k, v, causal, window, None,
                                       block_q, block_k, interpret,
                                       bwd_q, bwd_k)
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret)


def flash_attention(q, k, v, *, causal=True, window=0, policy=None,
                    block_q=None, block_k=None, interpret=None,
                    vjp_mode=None):
    """Blockwise attention, routed by ``policy.kernel_vjp`` (see module
    docstring). Any Sq/Sk is accepted; tail blocks are masked in-kernel."""
    shape = (q.shape[-2], k.shape[-2])
    (bq, bk), interp, mode = _route(
        "flash_attention", policy,
        {"block_q": block_q, "block_k": block_k}, interpret, vjp_mode,
        shape)
    bwq = bwk = None
    if mode == "fused":
        bwq, bwk = _bwd_blocks("flash_attention", policy, shape)
    return _flash_impl(q, k, v, causal=causal, window=window, block_q=bq,
                       block_k=bk, interpret=interp, vjp_mode=mode,
                       bwd_q=bwq, bwd_k=bwk)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret",
                                             "vjp_mode"))
def _ssd_impl(x, dt, a, b, c, initial_state, *, chunk, interpret, vjp_mode):
    if vjp_mode == "ref":
        return _ref.ssd(x, dt, a, b, c, initial_state=initial_state)
    if vjp_mode == "fused":
        if initial_state is None:
            bsz, _, H, P = x.shape
            initial_state = jnp.zeros((bsz, H, P, b.shape[3]), jnp.float32)
        return _ssd.ssd_scan_vjp(x, dt, a, b, c, initial_state, chunk,
                                 interpret)
    return _ssd.ssd_scan(x, dt, a, b, c, chunk=chunk, interpret=interpret,
                         initial_state=initial_state)


def ssd_scan(x, dt, a, b, c, initial_state=None, *, chunk=None,
             interpret=None, vjp_mode=None, policy=None):
    """SSD chunked scan, routed by ``policy.kernel_vjp`` (see module
    docstring). Any S is accepted (masked tail chunk); ``initial_state``
    (B,H,P,N) seeds the recurrence (prefill→decode handoff)."""
    (ck,), interp, mode = _route(
        "ssd_scan", policy, {"chunk": chunk}, interpret, vjp_mode,
        (x.shape[1],))
    return _ssd_impl(x, dt, a, b, c, initial_state,
                     chunk=min(ck, int(x.shape[1])), interpret=interp,
                     vjp_mode=mode)


# -------------------------------------------- paged_attention (serving) --

@functools.partial(jax.jit, static_argnames=("scale", "interpret",
                                             "vjp_mode"))
def _paged_impl(q, k_pool, v_pool, block_tables, seq_lens, *, scale,
                interpret, vjp_mode):
    if vjp_mode == "ref":
        return _ref.paged_attention(q, k_pool, v_pool, block_tables,
                                    seq_lens, scale=scale)
    return _pa.paged_attention(q, k_pool, v_pool, block_tables, seq_lens,
                               scale=scale, interpret=interpret)


def paged_attention(q, k_pool, v_pool, block_tables, seq_lens, *,
                    scale=None, policy=None):
    """Decode attention through a block-pool cache (DESIGN.md §12).

    q: (R, Hq, D); k/v_pool: (P, page, Hkv, D); block_tables: (R, M);
    seq_lens: (R,). Routed by ``policy.kernel_vjp`` like the training
    kernels — ``"ref"`` runs the gather-then-materialize oracle,
    anything else the streaming Pallas kernel (forward-only by
    construction: decode never differentiates, so there is no VJP pair).

    Unlike the other wrappers this one takes no block kwarg at all,
    legacy or otherwise: the registry's ``page`` entry is a *layout*
    property consumed once, at pool allocation (launch/paging.page_size);
    per-call geometry is fixed by ``k_pool.shape[1]``.
    """
    pol = B.resolve_exec_policy(policy)
    check_kernel_vjp_mode(pol.kernel_vjp)
    return _paged_impl(q, k_pool, v_pool, block_tables, seq_lens,
                       scale=scale, interpret=pol.interpret,
                       vjp_mode=pol.kernel_vjp)


# ------------------------------------------------- distill_kl (fused VJP)

def distill_kl(teacher_logits, student_logits, block_rows=None,
               block_v=None, interpret=None, with_teacher_grad=True, *,
               policy=None):
    """Per-row KL(softmax(t) ‖ softmax(s)), differentiable via the fused
    Pallas backward kernel (kernels/distill_kl.distill_kl_vjp). Any
    (R, V) shape is accepted; tail blocks are masked in-kernel. Always
    the kernel pair — ``policy`` only picks blocks and interpret-mode
    (the ref-vs-fused choice lives one level up, in
    core.losses.softmax_kl)."""
    legacy = block_rows is not None or block_v is not None \
        or interpret is not None
    pol = B.resolve_exec_policy(policy)
    if legacy:
        named = {k: v for k, v in (("block_rows", block_rows),
                                   ("block_v", block_v)) if v is not None}
        warnings.warn(
            "distill_kl: the positional block/interpret args are "
            "deprecated and will be removed in PR 11 (schedule in "
            "kernels/ops.py). Replace this call with\n"
            "    ops.distill_kl(t, s, policy="
            f"{_legacy_snippet('distill_kl', named, interpret, None)})",
            DeprecationWarning, stacklevel=2)
        pol = pol.override_blocks("distill_kl", block_rows=block_rows,
                                  block_v=block_v)
        if interpret is not None:
            pol = pol.replace(interpret=bool(interpret))
    shape = (teacher_logits.shape[0], teacher_logits.shape[1])
    if dict(pol.overrides).get("distill_kl") is None \
            and B.autotune_enabled():
        br, bv = B.autotune_blocks("distill_kl", shape, pol)
    else:
        br, bv = pol.blocks_for("distill_kl", shape)
    bwr, bwv = _bwd_blocks("distill_kl", pol, shape)
    return _kl.distill_kl_vjp(teacher_logits, student_logits, br, bv,
                              pol.interpret, with_teacher_grad, bwr, bwv)


def distill_kl_mean(teacher_logits, student_logits, **kw):
    """Scalar mean-KL convenience (Eq. 6 over a flattened token batch)."""
    r = distill_kl(teacher_logits, student_logits, **kw)
    return jnp.mean(r)
