"""Jitted public wrappers around the Pallas kernels.

On CPU (this container) kernels run with ``interpret=True`` (Pallas
executes the kernel body in Python) — correctness-validated against the
``ref.py`` oracles; on TPU they compile to Mosaic. ``interpret`` defaults
to auto-detection of the backend.

``distill_kl`` carries a custom VJP: the forward pass is the fused online
kernel; the backward pass uses the analytic gradients
  d/ds = softmax(s) − softmax(t),  d/dt = p ⊙ ((t−lse_t) − (s−lse_s) − KL)
evaluated in jnp (a fused backward kernel is a recorded §Perf follow-up).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import distill_kl as _kl
from repro.kernels import ssd_scan as _ssd
from repro.kernels import ref


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, block_q=128,
                    block_k=128, interpret=None):
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=_auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, a, b, c, *, chunk=128, interpret=None):
    return _ssd.ssd_scan(x, dt, a, b, c, chunk=chunk,
                         interpret=_auto_interpret(interpret))


# ------------------------------------------------- distill_kl + custom VJP

@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def distill_kl(teacher_logits, student_logits, block_rows=256, block_v=2048,
               interpret=None):
    return _kl.distill_kl(teacher_logits, student_logits,
                          block_rows=block_rows, block_v=block_v,
                          interpret=_auto_interpret(interpret))


def _kl_fwd(t, s, block_rows, block_v, interpret):
    kl = distill_kl(t, s, block_rows, block_v, interpret)
    return kl, (t, s, kl)


def _kl_bwd(block_rows, block_v, interpret, res, g):
    t, s, kl = res
    tf, sf = t.astype(jnp.float32), s.astype(jnp.float32)
    logp = jax.nn.log_softmax(tf, axis=-1)
    logq = jax.nn.log_softmax(sf, axis=-1)
    p, q = jnp.exp(logp), jnp.exp(logq)
    ds = (q - p) * g[:, None]
    dt = p * (logp - logq - kl[:, None]) * g[:, None]
    return dt.astype(t.dtype), ds.astype(s.dtype)


distill_kl.defvjp(_kl_fwd, _kl_bwd)


def distill_kl_mean(teacher_logits, student_logits, **kw):
    """Scalar mean-KL convenience (Eq. 6 over a flattened token batch)."""
    r = distill_kl(teacher_logits, student_logits, **kw)
    return jnp.mean(r)
