"""Jitted public wrappers around the Pallas kernels.

On CPU (this container) kernels run with ``interpret=True`` (Pallas
executes the kernel body in Python) — correctness-validated against the
``ref.py`` oracles; on TPU they compile to Mosaic. ``interpret`` defaults
to auto-detection of the backend.

Every differentiated kernel is a custom-VJP kernel *pair* (DESIGN.md §9):
the forward streams blocks with online accumulators and persists only
per-row/per-tile statistics as residuals, the backward is a second Pallas
kernel that re-streams the blocks to emit the gradients — no quadratic
softmax / state-history intermediate in HBM in either direction.

  * ``distill_kl``     — per-row online-LSE stats; the backward
    re-streams vocab blocks for dL/ds (and optionally dL/dt;
    ``with_teacher_grad=False`` skips that stream for stop-gradient'd
    teachers — DENSE's student step).
  * ``flash_attention``— per-row (m, l) softmax stats; the backward
    re-streams k-blocks (dq) and q-blocks (dk/dv, GQA group-accumulated
    in the revisited output block).
  * ``ssd_scan``       — per-chunk carried states; the backward walks
    the chunks in reverse carrying the state cotangent.

``vjp_mode`` routes flash_attention/ssd_scan (``scfg.kernel_vjp_mode``,
mirroring ``distill_kl_mode``):

  * ``"ref"``      — the pure-jnp oracle (materialized softmax /
    sequential recurrence), differentiated by jax autodiff. CPU-host
    default at the model layer.
  * ``"autodiff"`` — the forward Pallas kernel alone. Forward-only in
    practice: jax's pallas_call JVP rule rejects ``pl.program_id``
    bodies, so differentiating this path raises — kept as the
    no-gradient serving route and as documentation of WHY the kernel
    pairs exist.
  * ``"fused"``    — the custom-VJP kernel pair (the only differentiable
    kernel path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import distill_kl as _kl
from repro.kernels import ssd_scan as _ssd
from repro.kernels import ref as _ref

KERNEL_VJP_MODES = ("ref", "autodiff", "fused")


def check_kernel_vjp_mode(mode: str) -> None:
    """Fail fast on an unknown kernel_vjp_mode — part of the public
    contract (model applies and the dense_llm step builders validate at
    build time, before anything jits)."""
    if mode not in KERNEL_VJP_MODES:
        raise ValueError(f"unknown kernel_vjp mode {mode!r} "
                         f"(expected one of {KERNEL_VJP_MODES})")


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret",
                                             "vjp_mode"))
def flash_attention(q, k, v, *, causal=True, window=0, block_q=128,
                    block_k=128, interpret=None, vjp_mode="autodiff"):
    """Blockwise attention, routed by ``vjp_mode`` (see module docstring).
    Any Sq/Sk is accepted; tail blocks are masked in-kernel."""
    check_kernel_vjp_mode(vjp_mode)
    if vjp_mode == "ref":
        return _ref.attention(q, k, v, causal=causal, window=window)
    if vjp_mode == "fused":
        return _fa.flash_attention_vjp(q, k, v, causal, window, None,
                                       block_q, block_k,
                                       _auto_interpret(interpret))
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=_auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("chunk", "interpret",
                                             "vjp_mode"))
def ssd_scan(x, dt, a, b, c, initial_state=None, *, chunk=128,
             interpret=None, vjp_mode="autodiff"):
    """SSD chunked scan, routed by ``vjp_mode`` (see module docstring).
    Any S is accepted (masked tail chunk); ``initial_state`` (B,H,P,N)
    seeds the recurrence (prefill→decode handoff)."""
    check_kernel_vjp_mode(vjp_mode)
    if vjp_mode == "ref":
        return _ref.ssd(x, dt, a, b, c, initial_state=initial_state)
    if vjp_mode == "fused":
        if initial_state is None:
            B, _, H, P = x.shape
            initial_state = jnp.zeros((B, H, P, b.shape[3]), jnp.float32)
        return _ssd.ssd_scan_vjp(x, dt, a, b, c, initial_state, chunk,
                                 _auto_interpret(interpret))
    return _ssd.ssd_scan(x, dt, a, b, c, chunk=chunk,
                         interpret=_auto_interpret(interpret),
                         initial_state=initial_state)


# ------------------------------------------------- distill_kl (fused VJP)

def distill_kl(teacher_logits, student_logits, block_rows=256, block_v=2048,
               interpret=None, with_teacher_grad=True):
    """Per-row KL(softmax(t) ‖ softmax(s)), differentiable via the fused
    Pallas backward kernel (kernels/distill_kl.distill_kl_vjp). Any
    (R, V) shape is accepted; tail blocks are masked in-kernel."""
    return _kl.distill_kl_vjp(teacher_logits, student_logits, block_rows,
                              block_v, _auto_interpret(interpret),
                              with_teacher_grad)


def distill_kl_mean(teacher_logits, student_logits, **kw):
    """Scalar mean-KL convenience (Eq. 6 over a flattened token batch)."""
    r = distill_kl(teacher_logits, student_logits, **kw)
    return jnp.mean(r)
