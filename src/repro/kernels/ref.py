"""Pure-jnp oracles for every Pallas kernel in this package.

Each oracle uses the most *direct* formulation (materialized softmax,
step-by-step recurrence) so kernel tests compare two genuinely different
algorithms, not two copies of one.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


# -------------------------------------------------------- flash attention --

def attention(q, k, v, *, causal: bool = True, window: int = 0,
              scale: float | None = None):
    """Materialized-softmax attention (the O(S^2)-memory reference).

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D). GQA: Hq a multiple of Hkv.
    window w > 0 keeps keys with q_pos - k_pos < w (absolute positions
    assume q tokens are the last Sq of the Sk context).
    """
    B, Hq, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    g = Hq // Hkv
    if scale is None:
        scale = 1.0 / jnp.sqrt(D).astype(jnp.float32)
    qg = q.reshape(B, Hkv, g, Sq, D)
    scores = jnp.einsum("bkgsd,bktd->bkgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(Sq)[:, None] + (Sk - Sq)
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= q_pos - k_pos < window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,bktd->bkgsd", probs, v.astype(jnp.float32))
    return out.reshape(B, Hq, Sq, D).astype(q.dtype)


def attention_grads(q, k, v, g, *, causal: bool = True, window: int = 0,
                    scale: float | None = None):
    """Autodiff gradients of the materialized reference under output
    cotangent ``g`` — the ground truth for the streaming custom-VJP
    kernel pair (kernels/flash_attention.flash_attention_vjp).
    Deliberately routed through ``jax.vjp`` of the direct formulation,
    not the recomputed-p flash recurrence the backward kernels implement,
    so the test compares two genuinely different derivations."""
    _, pull = jax.vjp(
        lambda q_, k_, v_: attention(q_, k_, v_, causal=causal,
                                     window=window, scale=scale), q, k, v)
    return pull(g)


# --------------------------------------------------------- paged attention --

def paged_attention(q, k_pool, v_pool, block_tables, seq_lens, *,
                    scale=None):
    """Gather-then-materialize paged decode attention (the reference).

    q: (R, Hq, D); k/v_pool: (P, page, Hkv, D); block_tables: (R, M);
    seq_lens: (R,) live cached tokens per request. The oracle really
    gathers the whole (R, M*page) context per request and runs a
    materialized masked softmax — deliberately the opposite algorithm to
    the kernel's streamed per-block gather. ``seq_lens[r] == 0`` rows
    return exactly zero (matching the kernel's zero-mass finalize).
    """
    R, hq, d = q.shape
    _, page, hkv, _ = k_pool.shape
    m_slots = block_tables.shape[1]
    g = hq // hkv
    if scale is None:
        scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    # (R, M, page, Hkv, D) -> (R, T, Hkv, D), T = M * page
    k = k_pool[block_tables].reshape(R, m_slots * page, hkv, d)
    v = v_pool[block_tables].reshape(R, m_slots * page, hkv, d)
    qg = q.reshape(R, hkv, g, d).astype(jnp.float32)
    scores = jnp.einsum("rkgd,rtkd->rkgt", qg,
                        k.astype(jnp.float32)) * scale
    live = jnp.arange(m_slots * page)[None, :] < seq_lens[:, None]
    scores = jnp.where(live[:, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    p = jnp.where(live[:, None, None], p, 0.0)  # zero-live rows -> zeros
    out = jnp.einsum("rkgt,rtkd->rkgd", p, v.astype(jnp.float32))
    return out.reshape(R, hq, d).astype(q.dtype)


# --------------------------------------------------------------- ssd scan --

def ssd(x, dt, a, b, c, *, initial_state=None):
    """Step-by-step SSM recurrence (the O(S) sequential reference).

    x: (B,S,H,P), dt: (B,S,H), a: (H,), b/c: (B,S,G,N).
    s_t = exp(dt_t a) s_{t-1} + dt_t * (b_t ⊗ x_t);  y_t = c_t · s_t.
    Returns (y: (B,S,H,P), final_state: (B,H,P,N)).
    """
    B, S, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    rep = H // G
    bb = jnp.repeat(b, rep, axis=2).astype(jnp.float32)
    cc = jnp.repeat(c, rep, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    s0 = (jnp.zeros((B, H, P, N), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(s, t):
        xt, dtt, bt, ct = t
        da = jnp.exp(dtt * a[None, :])                       # (B,H)
        s = s * da[..., None, None] \
            + jnp.einsum("bh,bhp,bhn->bhpn", dtt, xt, bt)
        y = jnp.einsum("bhpn,bhn->bhp", s, ct)
        return s, y

    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(bb, 1, 0), jnp.moveaxis(cc, 1, 0))
    final, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), final


def ssd_grads(x, dt, a, b, c, initial_state, g_y, g_state):
    """Autodiff gradients of the sequential-recurrence reference under
    cotangents ``(g_y, g_state)`` — the ground truth for the
    reversed-recurrence custom-VJP kernel pair
    (kernels/ssd_scan.ssd_scan_vjp). Returns
    (dx, ddt, da, db, dc, dinitial_state)."""
    _, pull = jax.vjp(
        lambda *ar: ssd(*ar[:5], initial_state=ar[5]),
        x, dt, a, b, c, initial_state)
    return pull((g_y.astype(x.dtype), g_state.astype(jnp.float32)))


# ------------------------------------------------------------- distill KL --

def distill_kl(teacher_logits, student_logits):
    """Per-row KL(softmax(t) ‖ softmax(s)) with materialized softmaxes.

    (R, V) -> (R,) in float32.
    """
    t = teacher_logits.astype(jnp.float32)
    s = student_logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(t, axis=-1)
    logq = jax.nn.log_softmax(s, axis=-1)
    return jnp.sum(jnp.exp(logp) * (logp - logq), axis=-1)


def distill_kl_grads(teacher_logits, student_logits, g):
    """Autodiff gradients of the materialized reference under per-row
    cotangent ``g`` — the ground truth for the fused custom-VJP kernel
    pair (kernels/distill_kl.distill_kl_vjp). Deliberately routed through
    ``jax.vjp`` of the direct formulation, not the analytic formulas the
    backward kernel implements, so the test compares two genuinely
    different derivations."""
    _, pull = jax.vjp(distill_kl, teacher_logits, student_logits)
    return pull(g.astype(jnp.float32))
