"""Paged decode-attention: one-token queries against a block-pool cache.

Serving (DESIGN.md §12) stores each request's KV history as fixed-size
``page``-token blocks scattered across a shared pool, addressed through a
per-request block table (launch/paging.py). At decode time request ``r``
holds one incoming query token and ``seq_lens[r]`` live cached tokens;
this kernel gathers those k/v blocks *through the block table* and runs
the §9 streaming softmax over them — the pool is never re-packed into a
contiguous per-request cache.

House style (§9), adapted to decode:

  * grid ``(R, Hkv, M)`` — requests x kv-heads x table slots; the k/v
    BlockSpec index maps read the block id from the scalar-prefetched
    table (``pltpu.PrefetchScalarGridSpec``), so the gather IS the
    pipeline's block fetch — no materialized (R, M*page, ...) copy.
  * online (m, l) accumulators in revisited output blocks whose index
    maps ignore the innermost (table-slot) axis; init at ``j == 0``,
    finalize at ``j == M - 1``.
  * the probability block is computed UNDER the mask
    (``jnp.where(live, exp(s - m), 0)``) so table slots past the
    request's live length — including the all-zero table rows of
    inactive scheduler slots — contribute exactly nothing, even when
    every lane in the block is dead (the PR-5 dead-block lesson).

Decode-only, therefore forward-only: serving never differentiates
through the cache, so this kernel has no VJP pair — training-side
attention gradients remain flash_attention's (§9). Unlike the ragged
tails masked in-kernel elsewhere, here *every* block is potentially
ragged (a request rarely fills its last page), so the mask is
unconditional.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30  # finite sentinel: exp(NEG_INF - NEG_INF) stays defined


def _paged_kernel(seq_ref, bt_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                  *, scale, page, nb):
    r = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)                       # (G, D)
    k = k_ref[0, :, 0].astype(jnp.float32)                    # (page, D)
    v = v_ref[0, :, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    kpos = j * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
    live = kpos < seq_ref[r]                                  # (1, page)
    s = jnp.where(live, s, NEG_INF)

    m_prev = m_ref[0, 0]                                      # (G,)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    # p under the mask: a fully-dead block (slot past the live length, or
    # the null block of an inactive scheduler slot) must add zero mass,
    # not exp(NEG_INF - NEG_INF) = 1 per lane
    p = jnp.where(live, jnp.exp(s - m_new[:, None]), 0.0)     # (G, page)
    l_ref[0, 0] = l_ref[0, 0] * alpha + jnp.sum(p, axis=1)
    o_ref[0, 0] = o_ref[0, 0] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[0, 0] = m_new

    @pl.when(j == nb - 1)
    def _finalize():
        o_ref[0, 0] = o_ref[0, 0] / jnp.maximum(l_ref[0, 0], 1e-30)[:, None]


def paged_attention(q, k_pool, v_pool, block_tables, seq_lens, *,
                    scale=None, interpret=False):
    """Decode attention through a block table.

    q            : (R, Hq, D)   one incoming token per request slot
    k/v_pool     : (P, page, Hkv, D) shared block pools (one layer)
    block_tables : (R, M) int32 pool-block ids; slot ``j`` of request
                   ``r`` holds positions ``[j*page, (j+1)*page)``.
                   Unassigned entries must point at a real pool block
                   (the allocator reserves block 0 for this) — they are
                   masked out by ``seq_lens``, not by id.
    seq_lens     : (R,) int32 live cached tokens per request (the
                   incoming token's k/v included — scatter before call).

    Returns (R, Hq, D) in q.dtype. ``seq_lens[r] == 0`` rows (inactive
    scheduler slots) produce exactly zero.
    """
    R, hq, d = q.shape
    _, page, hkv, _ = k_pool.shape
    m_slots = block_tables.shape[1]
    g = hq // hkv
    assert hq == hkv * g and v_pool.shape == k_pool.shape
    if scale is None:
        scale = 1.0 / (d ** 0.5)

    grid = (R, hkv, m_slots)
    # index maps receive the scalar-prefetch refs last and return BLOCK
    # indices; the k/v maps are the paging gather
    q_spec = pl.BlockSpec((1, 1, g, d), lambda r, h, j, seq, bt: (r, h, 0, 0))
    kv_spec = pl.BlockSpec((1, page, 1, d),
                           lambda r, h, j, seq, bt: (bt[r, j], 0, h, 0))
    acc_specs = [
        pl.BlockSpec((1, 1, g, d), lambda r, h, j, seq, bt: (r, h, 0, 0)),
        pl.BlockSpec((1, 1, g), lambda r, h, j, seq, bt: (r, h, 0)),
        pl.BlockSpec((1, 1, g), lambda r, h, j, seq, bt: (r, h, 0)),
    ]
    o, _, _ = pl.pallas_call(
        functools.partial(_paged_kernel, scale=float(scale), page=page,
                          nb=m_slots),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2, grid=grid,
            in_specs=[q_spec, kv_spec, kv_spec], out_specs=acc_specs),
        out_shape=[jax.ShapeDtypeStruct((R, hkv, g, d), jnp.float32),
                   jax.ShapeDtypeStruct((R, hkv, g), jnp.float32),
                   jax.ShapeDtypeStruct((R, hkv, g), jnp.float32)],
        interpret=interpret,
    )(seq_lens.astype(jnp.int32), block_tables.astype(jnp.int32),
      q.reshape(R, hkv, g, d), k_pool, v_pool)
    return o.reshape(R, hq, d).astype(q.dtype)
