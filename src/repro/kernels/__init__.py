"""Pallas TPU kernels for the perf-critical compute layers, each with a
pure-jnp oracle in ref.py and a jitted wrapper in ops.py.

All three differentiated kernels are custom-VJP kernel *pairs*
(DESIGN.md §9): streaming forwards persisting only per-row/per-tile
statistics as residuals, plus streaming backward kernels —

  flash_attention — blockwise online-softmax attention (GQA + window);
                    backward re-streams k-/q-blocks from (m, l) row stats
  ssd_scan        — Mamba-2 SSD chunked scan (intra-chunk MXU matmuls +
                    VMEM-resident inter-chunk state, initial_state
                    seeding); backward walks chunks in reverse from the
                    per-chunk carried states
  distill_kl      — fused large-vocab KL for DENSE's distillation stage;
                    backward re-streams vocab blocks from online-LSE
                    stats

flash_attention/ssd_scan are routed by ``vjp_mode`` (ops.py /
``scfg.kernel_vjp_mode``): "ref" oracle, "autodiff" bare forward kernel
(not differentiable — jax's pallas_call JVP rule rejects the kernels),
"fused" custom-VJP pair.
"""
from repro.kernels.ops import (flash_attention, ssd_scan, distill_kl,
                               distill_kl_mean, check_kernel_vjp_mode,
                               KERNEL_VJP_MODES)
from repro.kernels import ref

__all__ = ["flash_attention", "ssd_scan", "distill_kl", "distill_kl_mean",
           "check_kernel_vjp_mode", "KERNEL_VJP_MODES", "ref"]
