"""Pallas TPU kernels for the perf-critical compute layers, each with a
pure-jnp oracle in ref.py and a jitted wrapper in ops.py.

All three differentiated kernels are custom-VJP kernel *pairs*
(DESIGN.md §9): streaming forwards persisting only per-row/per-tile
statistics as residuals, plus streaming backward kernels —

  flash_attention — blockwise online-softmax attention (GQA + window);
                    backward re-streams k-/q-blocks from (m, l) row stats
  ssd_scan        — Mamba-2 SSD chunked scan (intra-chunk MXU matmuls +
                    VMEM-resident inter-chunk state, initial_state
                    seeding); backward walks chunks in reverse from the
                    per-chunk carried states
  distill_kl      — fused large-vocab KL for DENSE's distillation stage;
                    backward re-streams vocab blocks from online-LSE
                    stats

flash_attention/ssd_scan are routed by the execution policy's
``kernel_vjp`` mode (ops.py; configs/backend.py, DESIGN.md §11 — the
backend registry picks the default, ``ArchConfig.kernel_vjp_mode`` pins
it): "ref" oracle, "autodiff" bare forward kernel (not differentiable —
jax's pallas_call JVP rule rejects the kernels), "fused" custom-VJP
pair. Block shapes and interpret-mode come from the same policy
(registry table + autotuner cache).
"""
from repro.kernels.ops import (flash_attention, ssd_scan, distill_kl,
                               distill_kl_mean, check_kernel_vjp_mode,
                               KERNEL_VJP_MODES)
from repro.kernels import ref

__all__ = ["flash_attention", "ssd_scan", "distill_kl", "distill_kl_mean",
           "check_kernel_vjp_mode", "KERNEL_VJP_MODES", "ref"]
