"""Pallas TPU kernels for the perf-critical compute layers, each with a
pure-jnp oracle in ref.py and a jitted wrapper in ops.py:

  flash_attention — blockwise online-softmax attention (GQA + window)
  ssd_scan        — Mamba-2 SSD chunked scan (intra-chunk MXU matmuls +
                    VMEM-resident inter-chunk state)
  distill_kl      — fused large-vocab KL for DENSE's distillation stage,
                    a custom-VJP kernel *pair*: per-row-stat residuals +
                    a streaming backward kernel (DESIGN.md §9)
"""
from repro.kernels.ops import (flash_attention, ssd_scan, distill_kl,
                               distill_kl_mean)
from repro.kernels import ref

__all__ = ["flash_attention", "ssd_scan", "distill_kl", "distill_kl_mean",
           "ref"]
