"""Blockwise (flash) attention Pallas TPU kernel *pair* — forward plus a
streaming custom-VJP backward (DESIGN.md §9).

Online-softmax attention with GQA and sliding-window support. VMEM
footprint per grid step is O(bq*D + bk*D + bq*bk) instead of O(Sq*Sk).

TPU adaptation notes (DESIGN.md §3): running max/denominator and the
output accumulator live in *revisited output blocks* — their index maps
ignore the k-block grid axis, so Pallas keeps them resident in VMEM across
the innermost loop (the TPU-idiomatic replacement for CUDA shared-memory
accumulators). Block sizes default to MXU-friendly multiples of 128.

Differentiation (``flash_attention_vjp``): jax autodiff cannot transpose
this kernel — the pallas_call JVP rule rejects ``pl.program_id`` bodies
outright, and even where it applied it would rematerialize the (Sq, Sk)
probability matrix the forward streams to avoid. Instead the forward
persists only the per-row softmax statistic ``lse = m + log l`` (plus the
f32 output, consumed as ``delta = Σ_d dO⊙O``) and two backward kernels
re-stream the blocks with the standard recomputed-p flash recurrence:

  p    = exp(q·kᵀ·scale − lse)            (recomputed per block)
  dv  += pᵀ · dO
  ds   = p ⊙ (dO·vᵀ − delta)
  dq  += ds · k · scale                    (k-block stream per q row)
  dk  += dsᵀ · q · scale                   (q-block stream per k row)

— no (Sq, Sk) intermediate in HBM in either direction. GQA: the dk/dv
grid walks the g query heads of each kv head in its innermost axis, so
group accumulation happens in the revisited output block.

Ragged shapes are handled in-kernel like ``distill_kl``: tail k-blocks
are masked to NEG_INF before any arithmetic and garbage tail *values* are
zeroed (Pallas pads out-of-range block reads with undefined values — NaN
in interpret mode), ragged q rows rely on out-of-bounds writes being
dropped — no Sq % bq / Sk % bk restriction. A block whose keys are ALL
masked (short sliding window, tail) contributes exactly nothing: ``p`` is
forced to zero under the mask. The former ``exp(NEG_INF − NEG_INF) = 1``
lanes inflated ``l`` while ``m == NEG_INF`` — washed out of ``o`` by
alpha underflow once a live block arrived, but corrupting the persisted
``(m, l)`` statistic (the residual the backward's recomputed ``p``
divides by) for rows with no live key at all (causal with Sq > Sk,
ragged tails): ``l`` is now exactly the live softmax mass, zero for such
rows, pinning their ``lse`` to NEG_INF and their backward contribution
to zero.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -2.0 ** 30


def _block_mask(i, j, *, bq: int, bk: int, causal: bool, window: int,
                seq_off: int, sq: int, sk: int, mask_q_tail: bool,
                mask_k_tail: bool):
    """(bq, bk) validity mask for q-block i vs k-block j.

    Shared by the forward and both backward kernels so the three streams
    see the identical mask (causal, sliding window, and — when the
    sequence is not a block multiple — the ragged tail lanes)."""
    q_idx = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_idx = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    q_pos = q_idx + seq_off
    mask = jnp.ones((bq, bk), bool)
    if causal:
        mask &= k_idx <= q_pos
    if window:
        mask &= q_pos - k_idx < window
    if mask_q_tail:
        mask &= q_idx < sq
    if mask_k_tail:
        mask &= k_idx < sk
    return mask


def _zero_tail_rows(x, blk, bsz: int, n: int):
    """Zero the out-of-range rows of a (bsz, D) block: Pallas fills OOB
    reads with undefined values (NaN in interpret mode) which would
    otherwise poison cross-row reductions/matmuls."""
    idx = blk * bsz + jax.lax.broadcasted_iota(jnp.int32, (bsz, 1), 0)
    return jnp.where(idx < n, x, 0.0)


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *,
                  scale: float, bq: int, bk: int, nk: int, causal: bool,
                  window: int, seq_off: int, sq: int, sk: int,
                  mask_k_tail: bool):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                     # (bq, D)
    k = k_ref[0].astype(jnp.float32)                     # (bk, D)
    v = v_ref[0].astype(jnp.float32)                     # (bk, D)
    if mask_k_tail:
        # garbage v rows meet exact-zero p lanes below, but 0 * NaN = NaN
        v = _zero_tail_rows(v, j, bk, sk)
    # ragged q rows need no zeroing here: every op below is row-local, so
    # their NaNs stay in rows the out-of-bounds write drops

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    i = pl.program_id(1)
    # mask_q_tail stays False here: the forward is row-local, so ragged q
    # rows quarantine their own NaNs and are dropped on write
    mask = _block_mask(i, j, bq=bq, bk=bk, causal=causal, window=window,
                       seq_off=seq_off, sq=sq, sk=sk,
                       mask_q_tail=False, mask_k_tail=mask_k_tail)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[0]                                    # (bq,)
    l_prev = l_ref[0]
    m_cur = jnp.max(s, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    # p under the mask, NOT exp(s - m_new): a fully-masked block (short
    # window / ragged tail) has m_new == NEG_INF, where exp(s - m_new)
    # = exp(0) = 1 per lane — inflating l by bk per dead block while no
    # live key has been seen. Harmless to o (alpha underflows the stale l
    # away at the first live block; never-live rows emit 0 either way)
    # but fatal to the persisted stats: l must be the exact live mass for
    # lse = m + log l to be the backward's softmax denominator, and
    # exactly 0 for never-live rows so their lse pins to NEG_INF
    p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
    l_new = l_prev * alpha + jnp.sum(p, axis=1)
    o_ref[0] = o_ref[0] * alpha[:, None] \
        + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    m_ref[0] = m_new
    l_ref[0] = l_new

    @pl.when(j == nk - 1)
    def _finalize():
        o_ref[0] = o_ref[0] / jnp.maximum(l_ref[0], 1e-30)[:, None]


def _blocking(Sq: int, Sk: int, block_q: int, block_k: int):
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    nq, nk = pl.cdiv(Sq, bq), pl.cdiv(Sk, bk)
    return bq, bk, nq, nk, (Sq % bq) != 0, (Sk % bk) != 0


def _fwd_flat(qf, kf, vf, *, Hq, Hkv, causal, window, scale, block_q,
              block_k, interpret):
    """Flattened-head forward: qf (B*Hq, Sq, D), kf/vf (B*Hkv, Sk, D)
    -> (o, m, l) with o float32 (the per-row stats are the VJP residual)."""
    BH, Sq, D = qf.shape
    Sk = kf.shape[1]
    g = Hq // Hkv
    bq, bk, nq, nk, mq, mk = _blocking(Sq, Sk, block_q, block_k)

    def q_map(bh, i, j):
        return (bh, i, 0)

    def kv_map(bh, i, j):
        return ((bh // Hq) * Hkv + (bh % Hq) // g, j, 0)

    def ml_map(bh, i, j):
        return (bh, i)

    return pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, bq=bq, bk=bk, nk=nk,
                          causal=causal, window=window, seq_off=Sk - Sq,
                          sq=Sq, sk=Sk, mask_k_tail=mk),
        grid=(BH, nq, nk),
        in_specs=[pl.BlockSpec((1, bq, D), q_map),
                  pl.BlockSpec((1, bk, D), kv_map),
                  pl.BlockSpec((1, bk, D), kv_map)],
        out_specs=[pl.BlockSpec((1, bq, D), q_map),
                   pl.BlockSpec((1, bq), ml_map),
                   pl.BlockSpec((1, bq), ml_map)],
        out_shape=[jax.ShapeDtypeStruct((BH, Sq, D), jnp.float32),
                   jax.ShapeDtypeStruct((BH, Sq), jnp.float32),
                   jax.ShapeDtypeStruct((BH, Sq), jnp.float32)],
        interpret=interpret,
    )(qf, kf, vf)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: float | None = None, block_q: int,
                    block_k: int, interpret: bool = False,
                    return_stats: bool = False):
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D) -> (B, Hq, Sq, D).

    GQA handled by the k/v index maps (Hq = g * Hkv). ``window`` keeps
    keys with q_pos - k_pos < window (q tokens are the last Sq of Sk).
    Any Sq/Sk is accepted: tail blocks are masked in-kernel, ragged q
    rows rely on out-of-bounds writes being dropped. With
    ``return_stats=True`` additionally returns ``(o_f32, lse)`` on the
    flattened (B*Hq, ...) view — the custom-VJP residuals (persisted
    instead of recomputed).
    """
    B, Hq, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    if scale is None:
        scale = float(1.0 / (D ** 0.5))
    out, m, l = _fwd_flat(q.reshape(B * Hq, Sq, D),
                          k.reshape(B * Hkv, Sk, D),
                          v.reshape(B * Hkv, Sk, D),
                          Hq=Hq, Hkv=Hkv, causal=causal, window=window,
                          scale=scale, block_q=block_q, block_k=block_k,
                          interpret=interpret)
    if return_stats:
        # fold (m, l) -> lse once per row; rows that never saw a live key
        # (l == 0) pin to NEG_INF so the backward's exp stays finite
        lse = jnp.where(l > 0, m + jnp.log(jnp.maximum(l, 1e-30)), NEG_INF)
        return out.reshape(B, Hq, Sq, D).astype(q.dtype), out, lse
    return out.reshape(B, Hq, Sq, D).astype(q.dtype)


# ------------------------------------------------------- fused backward --

def _flash_bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, d_ref,
                         dq_ref, *, scale: float, bq: int, bk: int,
                         causal: bool, window: int, seq_off: int, sq: int,
                         sk: int, mask_k_tail: bool):
    """dq for one q block, streaming k blocks (grid = fwd grid). Row-local
    except the k/v reads, so ragged q rows self-quarantine as in the
    forward."""
    i, j = pl.program_id(1), pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        dq_ref[...] = jnp.zeros_like(dq_ref)

    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)
    lse = lse_ref[0]                                     # (bq,)
    delta = d_ref[0]
    if mask_k_tail:
        k = _zero_tail_rows(k, j, bk, sk)
        v = _zero_tail_rows(v, j, bk, sk)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    mask = _block_mask(i, j, bq=bq, bk=bk, causal=causal, window=window,
                       seq_off=seq_off, sq=sq, sk=sk,
                       mask_q_tail=False, mask_k_tail=mask_k_tail)
    p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta[:, None])
    dq_ref[0] = dq_ref[0] + jax.lax.dot_general(
        ds, k, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) * scale


def _flash_bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, d_ref,
                          dk_ref, dv_ref, *, scale: float, bq: int,
                          bk: int, nq: int, causal: bool, window: int,
                          seq_off: int, sq: int, sk: int,
                          mask_q_tail: bool, mask_k_tail: bool):
    """dk/dv for one k block, streaming q blocks. The innermost grid axis
    enumerates (query head in group) x (q block), so GQA group summation
    lands in the revisited dk/dv blocks. Garbage q-tail rows WOULD cross
    rows here (they enter k-row reductions), so they are zeroed and
    masked, unlike the row-local kernels."""
    j, t = pl.program_id(1), pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        dk_ref[...] = jnp.zeros_like(dk_ref)
        dv_ref[...] = jnp.zeros_like(dv_ref)

    i = t % nq
    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)
    lse = lse_ref[0]
    delta = d_ref[0]
    if mask_q_tail:
        q = _zero_tail_rows(q, i, bq, sq)
        do = _zero_tail_rows(do, i, bq, sq)
        row = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq,), 0)
        lse = jnp.where(row < sq, lse, 0.0)
        delta = jnp.where(row < sq, delta, 0.0)
    if mask_k_tail:
        k = _zero_tail_rows(k, j, bk, sk)
        v = _zero_tail_rows(v, j, bk, sk)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    mask = _block_mask(i, j, bq=bq, bk=bk, causal=causal, window=window,
                       seq_off=seq_off, sq=sq, sk=sk,
                       mask_q_tail=mask_q_tail, mask_k_tail=mask_k_tail)
    p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)
    dv_ref[0] = dv_ref[0] + jax.lax.dot_general(
        p, do, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta[:, None])
    dk_ref[0] = dk_ref[0] + jax.lax.dot_general(
        ds, q, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) * scale


def flash_attention_bwd(q, k, v, o_f32, lse, do, *, causal: bool = True,
                        window: int = 0, scale: float | None = None,
                        block_q: int, block_k: int,
                        interpret: bool = False):
    """Stream the attention gradients from per-row stats: (dq, dk, dv).

    o_f32/lse are the forward's flattened residuals; the (Sq, Sk)
    probability matrix is recomputed block-by-block, never materialized.
    """
    B, Hq, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    g = Hq // Hkv
    if scale is None:
        scale = float(1.0 / (D ** 0.5))
    bq, bk, nq, nk, mq, mk = _blocking(Sq, Sk, block_q, block_k)

    qf = q.reshape(B * Hq, Sq, D)
    kf = k.reshape(B * Hkv, Sk, D)
    vf = v.reshape(B * Hkv, Sk, D)
    dof = do.astype(jnp.float32).reshape(B * Hq, Sq, D)
    delta = jnp.sum(dof * o_f32, axis=-1)                # (B*Hq, Sq)

    def q_map(bh, i, j):
        return (bh, i, 0)

    def kv_map(bh, i, j):
        return ((bh // Hq) * Hkv + (bh % Hq) // g, j, 0)

    def ml_map(bh, i, j):
        return (bh, i)

    dq = pl.pallas_call(
        functools.partial(_flash_bwd_dq_kernel, scale=scale, bq=bq, bk=bk,
                          causal=causal, window=window, seq_off=Sk - Sq,
                          sq=Sq, sk=Sk, mask_k_tail=mk),
        grid=(B * Hq, nq, nk),
        in_specs=[pl.BlockSpec((1, bq, D), q_map),
                  pl.BlockSpec((1, bk, D), kv_map),
                  pl.BlockSpec((1, bk, D), kv_map),
                  pl.BlockSpec((1, bq, D), q_map),
                  pl.BlockSpec((1, bq), ml_map),
                  pl.BlockSpec((1, bq), ml_map)],
        out_specs=[pl.BlockSpec((1, bq, D), q_map)],
        out_shape=[jax.ShapeDtypeStruct((B * Hq, Sq, D), jnp.float32)],
        interpret=interpret,
    )(qf, kf, vf, dof, lse, delta)[0]

    # dk/dv: grid (kv head, k block, g * nq) — the t axis walks every
    # (query head of the group, q block) pair with the dk/dv block
    # resident, so GQA accumulation never materializes per-q-head copies
    def qt_map(bh, j, t):
        return ((bh // Hkv) * Hq + (bh % Hkv) * g + t // nq, t % nq, 0)

    def kt_map(bh, j, t):
        return (bh, j, 0)

    def mlt_map(bh, j, t):
        return ((bh // Hkv) * Hq + (bh % Hkv) * g + t // nq, t % nq)

    dk, dv = pl.pallas_call(
        functools.partial(_flash_bwd_dkv_kernel, scale=scale, bq=bq,
                          bk=bk, nq=nq, causal=causal, window=window,
                          seq_off=Sk - Sq, sq=Sq, sk=Sk, mask_q_tail=mq,
                          mask_k_tail=mk),
        grid=(B * Hkv, nk, g * nq),
        in_specs=[pl.BlockSpec((1, bq, D), qt_map),
                  pl.BlockSpec((1, bk, D), kt_map),
                  pl.BlockSpec((1, bk, D), kt_map),
                  pl.BlockSpec((1, bq, D), qt_map),
                  pl.BlockSpec((1, bq), mlt_map),
                  pl.BlockSpec((1, bq), mlt_map)],
        out_specs=[pl.BlockSpec((1, bk, D), kt_map),
                   pl.BlockSpec((1, bk, D), kt_map)],
        out_shape=[jax.ShapeDtypeStruct((B * Hkv, Sk, D), jnp.float32),
                   jax.ShapeDtypeStruct((B * Hkv, Sk, D), jnp.float32)],
        interpret=interpret,
    )(qf, kf, vf, dof, lse, delta)

    return (dq.reshape(B, Hq, Sq, D).astype(q.dtype),
            dk.reshape(B, Hkv, Sk, D).astype(k.dtype),
            dv.reshape(B, Hkv, Sk, D).astype(v.dtype))


# ------------------------------------------------------------ custom VJP --

@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7, 8, 9, 10))
def flash_attention_vjp(q, k, v, causal, window, scale,
                        block_q, block_k, interpret=False,
                        bwd_q=None, bwd_k=None):
    """flash_attention with the streaming Pallas backward (DESIGN.md §9).

    Residual contract: only the inputs (alive anyway), the f32 output and
    the per-row ``lse`` statistic are saved — the backward re-streams the
    q/k blocks, so neither pass materializes the (Sq, Sk) probability
    matrix in HBM. Also the only *differentiable* kernel path: jax
    autodiff through the forward pallas_call raises (its JVP rule rejects
    ``pl.program_id``).

    ``bwd_q``/``bwd_k`` (None -> reuse the forward blocks) give the
    backward its OWN tile shapes: the dq pass streams k-blocks per
    q-block while the dk/dv pass streams q-blocks per k-block, a
    different traffic pattern from the forward — the registry/autotuner
    resolve them under the separate ``flash_attention_bwd`` kernel entry
    (configs/backend.py, DESIGN.md §11)."""
    return flash_attention(q, k, v, causal=causal, window=window,
                           scale=scale, block_q=block_q, block_k=block_k,
                           interpret=interpret)


def _vjp_fwd(q, k, v, causal, window, scale, block_q, block_k, interpret,
             bwd_q, bwd_k):
    out, o_f32, lse = flash_attention(
        q, k, v, causal=causal, window=window, scale=scale,
        block_q=block_q, block_k=block_k, interpret=interpret,
        return_stats=True)
    return out, (q, k, v, o_f32, lse)


def _vjp_bwd(causal, window, scale, block_q, block_k, interpret,
             bwd_q, bwd_k, res, g):
    q, k, v, o_f32, lse = res
    return flash_attention_bwd(q, k, v, o_f32, lse, g, causal=causal,
                               window=window, scale=scale,
                               block_q=bwd_q if bwd_q else block_q,
                               block_k=bwd_k if bwd_k else block_k,
                               interpret=interpret)


flash_attention_vjp.defvjp(_vjp_fwd, _vjp_bwd)
