"""Blockwise (flash) attention Pallas TPU kernel.

Online-softmax attention with GQA and sliding-window support. VMEM
footprint per grid step is O(bq*D + bk*D + bq*bk) instead of O(Sq*Sk).

TPU adaptation notes (DESIGN.md §3): running max/denominator and the
output accumulator live in *revisited output blocks* — their index maps
ignore the k-block grid axis, so Pallas keeps them resident in VMEM across
the innermost loop (the TPU-idiomatic replacement for CUDA shared-memory
accumulators). Block sizes default to MXU-friendly multiples of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -2.0 ** 30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *,
                  scale: float, bq: int, bk: int, nk: int, causal: bool,
                  window: int, seq_off: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                     # (bq, D)
    k = k_ref[0].astype(jnp.float32)                     # (bk, D)
    v = v_ref[0].astype(jnp.float32)                     # (bk, D)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    i = pl.program_id(1)
    q_pos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + seq_off
    k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[0]                                    # (bq,)
    l_prev = l_ref[0]
    m_cur = jnp.max(s, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_prev * alpha + jnp.sum(p, axis=1)
    o_ref[0] = o_ref[0] * alpha[:, None] \
        + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    m_ref[0] = m_new
    l_ref[0] = l_new

    @pl.when(j == nk - 1)
    def _finalize():
        o_ref[0] = o_ref[0] / jnp.maximum(l_ref[0], 1e-30)[:, None]


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: float | None = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D) -> (B, Hq, Sq, D).

    GQA handled by the k/v index maps (Hq = g * Hkv). ``window`` keeps
    keys with q_pos - k_pos < window (q tokens are the last Sq of Sk).
    """
    B, Hq, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    g = Hq // Hkv
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    nq, nk = Sq // bq, Sk // bk
    if scale is None:
        scale = float(1.0 / (D ** 0.5))

    qf = q.reshape(B * Hq, Sq, D)
    kf = k.reshape(B * Hkv, Sk, D)
    vf = v.reshape(B * Hkv, Sk, D)

    def q_map(bh, i, j):
        return (bh, i, 0)

    def kv_map(bh, i, j):
        return ((bh // Hq) * Hkv + (bh % Hq) // g, j, 0)

    def o_map(bh, i, j):
        return (bh, i, 0)

    def ml_map(bh, i, j):
        return (bh, i)

    out, _, _ = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, bq=bq, bk=bk, nk=nk,
                          causal=causal, window=window, seq_off=Sk - Sq),
        grid=(B * Hq, nq, nk),
        in_specs=[pl.BlockSpec((1, bq, D), q_map),
                  pl.BlockSpec((1, bk, D), kv_map),
                  pl.BlockSpec((1, bk, D), kv_map)],
        out_specs=[pl.BlockSpec((1, bq, D), o_map),
                   pl.BlockSpec((1, bq), ml_map),
                   pl.BlockSpec((1, bq), ml_map)],
        out_shape=[jax.ShapeDtypeStruct((B * Hq, Sq, D), jnp.float32),
                   jax.ShapeDtypeStruct((B * Hq, Sq), jnp.float32),
                   jax.ShapeDtypeStruct((B * Hq, Sq), jnp.float32)],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, Hq, Sq, D).astype(q.dtype)
