"""Mamba-2 SSD chunked-scan Pallas TPU kernel (arXiv:2405.21060).

Per (batch, head) the sequence is processed in chunks: the intra-chunk
quadratic term is a masked (cl x cl) matmul — MXU work — and the running
SSM state (P x N) is carried across chunk grid steps in a revisited output
block (stays resident in VMEM; the chunk axis is the innermost grid dim,
which Pallas TPU executes sequentially).

This is the TPU-native adaptation of the paper-adjacent GPU scan: no warp
shuffles / selective-scan CUDA kernel, instead blockwise matmuls shaped
for the MXU + a VMEM-resident recurrence.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref, *,
                cl: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0].astype(jnp.float32)                # (cl, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)              # (cl,)
    a = a_ref[0].astype(jnp.float32)                      # scalar
    bmat = b_ref[0, :, 0].astype(jnp.float32)             # (cl, N)
    cmat = c_ref[0, :, 0].astype(jnp.float32)             # (cl, N)

    da = dt * a                                           # (cl,) log-decays
    cs = jnp.cumsum(da)                                   # within-chunk cumsum

    # intra-chunk: att[l, s] = (c_l . b_s) e^{cs_l - cs_s} dt_s for l >= s
    seg = cs[:, None] - cs[None, :]
    tril = jax.lax.broadcasted_iota(jnp.int32, (cl, cl), 0) \
        >= jax.lax.broadcasted_iota(jnp.int32, (cl, cl), 1)
    decay = jnp.where(tril, jnp.exp(seg), 0.0)
    cb = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    att = cb * decay * dt[None, :]
    y_diag = jax.lax.dot_general(att, x, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)

    # inter-chunk: y_off[l] = e^{cs_l} * (c_l . S_prev)
    state = state_ref[0, 0]                               # (P, N)
    y_off = jnp.exp(cs)[:, None] * jax.lax.dot_general(
        cmat, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)               # (cl, P)

    y_ref[0, :, 0] = (y_diag + y_off).astype(y_ref.dtype)

    # state update: S <- e^{cs_end} S + sum_l e^{cs_end - cs_l} dt_l x_l b_l^T
    w = dt * jnp.exp(cs[-1] - cs)                         # (cl,)
    outer = jax.lax.dot_general(x * w[:, None], bmat,
                                (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (P, N)
    state_ref[0, 0] = jnp.exp(cs[-1]) * state + outer


def ssd_scan(x, dt, a, b, c, *, chunk: int = 128, interpret: bool = False):
    """SSD forward. x:(B,S,H,P) dt:(B,S,H) a:(H,) b/c:(B,S,G,N).

    Returns (y: (B,S,H,P), final_state: (B,H,P,N)). G groups broadcast over
    heads via the b/c index maps (no repeat materialized).
    """
    B, S, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    cl = min(chunk, S)
    assert S % cl == 0, (S, cl)
    nc = S // cl

    y, state = pl.pallas_call(
        functools.partial(_ssd_kernel, cl=cl),
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, cl, 1, P), lambda bi, h, ci: (bi, ci, h, 0)),
            pl.BlockSpec((1, cl, 1), lambda bi, h, ci: (bi, ci, h)),
            pl.BlockSpec((1,), lambda bi, h, ci: (h,)),
            pl.BlockSpec((1, cl, 1, N),
                         lambda bi, h, ci: (bi, ci, h * G // H, 0)),
            pl.BlockSpec((1, cl, 1, N),
                         lambda bi, h, ci: (bi, ci, h * G // H, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, cl, 1, P), lambda bi, h, ci: (bi, ci, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda bi, h, ci: (bi, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, a, b, c)
    return y, state
