"""Mamba-2 SSD chunked-scan Pallas TPU kernel *pair* (arXiv:2405.21060) —
forward plus a streaming custom-VJP backward (DESIGN.md §9).

Per (batch, head) the sequence is processed in chunks: the intra-chunk
quadratic term is a masked (cl x cl) matmul — MXU work — and the running
SSM state (P x N) is carried across chunk grid steps in a revisited output
block (stays resident in VMEM; the chunk axis is the innermost grid dim,
which Pallas TPU executes sequentially).

This is the TPU-native adaptation of the paper-adjacent GPU scan: no warp
shuffles / selective-scan CUDA kernel, instead blockwise matmuls shaped
for the MXU + a VMEM-resident recurrence.

Differentiation (``ssd_scan_vjp``): jax autodiff cannot transpose this
kernel (the pallas_call JVP rule rejects ``pl.program_id`` bodies), and
an unrolled-recurrence formulation would keep the full (S, P, N) state
history alive between the passes. Instead the forward persists only the
per-chunk *carried* states (nc = ceil(S/chunk) snapshots, the state
entering each chunk) and the backward kernel walks the chunks in REVERSE,
carrying the state cotangent dS in a revisited output block and
recomputing each chunk's intra-chunk quantities from the inputs — the
state history between chunk boundaries is never materialized in either
pass. The dS carry's final content is d(initial_state) for free.

Ragged lengths are handled in-kernel: the tail chunk's out-of-range lanes
are zeroed before any arithmetic (dt = 0 ⇒ zero decay and zero state
deposit, so the masked tail contributes nothing to the carried state) —
no S % chunk restriction. ``initial_state`` seeds the recurrence (the
prefill→decode handoff the kernel used to silently drop: it zeroed the
state carry unconditionally while the ``ref.ssd`` oracle honored it).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _load_chunk(x_ref, dt_ref, b_ref, c_ref, ci, *, cl: int, S: int,
                mask_tail: bool):
    """Load one chunk's operands in f32, zeroing the ragged tail lanes.

    dt = 0 on a masked lane kills every coupling of that lane: its decay
    contribution (da = dt*a = 0 keeps the cumsum flat), its intra-chunk
    column (att carries a dt_s factor) and its state deposit (w = dt * e).
    x/b/c are zeroed too because Pallas pads out-of-range reads with
    undefined values (NaN in interpret mode) and 0 * NaN = NaN."""
    x = x_ref[0, :, 0].astype(jnp.float32)                # (cl, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)              # (cl,)
    bmat = b_ref[0, :, 0].astype(jnp.float32)             # (cl, N)
    cmat = c_ref[0, :, 0].astype(jnp.float32)             # (cl, N)
    if mask_tail:
        pos = ci * cl + jax.lax.broadcasted_iota(jnp.int32, (cl, 1), 0)
        valid = pos < S
        x = jnp.where(valid, x, 0.0)
        dt = jnp.where(valid[:, 0], dt, 0.0)
        bmat = jnp.where(valid, bmat, 0.0)
        cmat = jnp.where(valid, cmat, 0.0)
    return x, dt, bmat, cmat


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, init_ref, y_ref,
                state_ref, *opt_refs, cl: int, S: int, mask_tail: bool,
                save_states: bool):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[0, 0] = init_ref[0, 0].astype(jnp.float32)

    if save_states:
        # persist the state ENTERING this chunk — the custom-VJP residual
        opt_refs[0][0, 0, 0] = state_ref[0, 0]

    x, dt, bmat, cmat = _load_chunk(x_ref, dt_ref, b_ref, c_ref, ci,
                                    cl=cl, S=S, mask_tail=mask_tail)
    a = a_ref[0].astype(jnp.float32)                      # scalar

    da = dt * a                                           # (cl,) log-decays
    cs = jnp.cumsum(da)                                   # within-chunk cumsum

    # intra-chunk: att[l, s] = (c_l . b_s) e^{cs_l - cs_s} dt_s for l >= s
    seg = cs[:, None] - cs[None, :]
    tril = jax.lax.broadcasted_iota(jnp.int32, (cl, cl), 0) \
        >= jax.lax.broadcasted_iota(jnp.int32, (cl, cl), 1)
    decay = jnp.where(tril, jnp.exp(seg), 0.0)
    cb = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    att = cb * decay * dt[None, :]
    y_diag = jax.lax.dot_general(att, x, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)

    # inter-chunk: y_off[l] = e^{cs_l} * (c_l . S_prev)
    state = state_ref[0, 0]                               # (P, N)
    y_off = jnp.exp(cs)[:, None] * jax.lax.dot_general(
        cmat, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)               # (cl, P)

    y_ref[0, :, 0] = (y_diag + y_off).astype(y_ref.dtype)

    # state update: S <- e^{cs_end} S + sum_l e^{cs_end - cs_l} dt_l x_l b_l^T
    w = dt * jnp.exp(cs[-1] - cs)                         # (cl,)
    outer = jax.lax.dot_general(x * w[:, None], bmat,
                                (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (P, N)
    state_ref[0, 0] = jnp.exp(cs[-1]) * state + outer


def ssd_scan(x, dt, a, b, c, *, chunk: int, interpret: bool = False,
             initial_state=None, return_chunk_states: bool = False):
    """SSD forward. x:(B,S,H,P) dt:(B,S,H) a:(H,) b/c:(B,S,G,N).

    Returns (y: (B,S,H,P), final_state: (B,H,P,N)). G groups broadcast over
    heads via the b/c index maps (no repeat materialized). Any S is
    accepted (the tail chunk is masked in-kernel). ``initial_state``
    (B,H,P,N) seeds the recurrence — the prefill→decode handoff.
    ``return_chunk_states=True`` additionally returns the (B,H,nc,P,N)
    per-chunk carried states — the custom-VJP residuals (persisted
    instead of recomputed).
    """
    B, S, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    cl = min(chunk, S)
    nc = pl.cdiv(S, cl)
    if initial_state is None:
        initial_state = jnp.zeros((B, H, P, N), jnp.float32)

    out_specs = [
        pl.BlockSpec((1, cl, 1, P), lambda bi, h, ci: (bi, ci, h, 0)),
        pl.BlockSpec((1, 1, P, N), lambda bi, h, ci: (bi, h, 0, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((B, S, H, P), x.dtype),
        jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
    ]
    if return_chunk_states:
        out_specs.append(pl.BlockSpec((1, 1, 1, P, N),
                                      lambda bi, h, ci: (bi, h, ci, 0, 0)))
        out_shape.append(jax.ShapeDtypeStruct((B, H, nc, P, N), jnp.float32))

    outs = pl.pallas_call(
        functools.partial(_ssd_kernel, cl=cl, S=S, mask_tail=(S % cl) != 0,
                          save_states=return_chunk_states),
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, cl, 1, P), lambda bi, h, ci: (bi, ci, h, 0)),
            pl.BlockSpec((1, cl, 1), lambda bi, h, ci: (bi, ci, h)),
            pl.BlockSpec((1,), lambda bi, h, ci: (h,)),
            pl.BlockSpec((1, cl, 1, N),
                         lambda bi, h, ci: (bi, ci, h * G // H, 0)),
            pl.BlockSpec((1, cl, 1, N),
                         lambda bi, h, ci: (bi, ci, h * G // H, 0)),
            pl.BlockSpec((1, 1, P, N), lambda bi, h, ci: (bi, h, 0, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(x, dt, a, b, c, initial_state)
    if return_chunk_states:
        return outs[0], outs[1], outs[2]
    return outs[0], outs[1]


# ------------------------------------------------------- fused backward --

def _ssd_bwd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, st_ref, dy_ref,
                    dfin_ref, dx_ref, ddt_ref, dbh_ref, dch_ref, dap_ref,
                    dinit_ref, *, cl: int, nc: int, S: int,
                    mask_tail: bool):
    """One chunk of the reversed inter-chunk recurrence.

    The grid's innermost axis runs ci = 0..nc-1 while every index map
    reads chunk rc = nc-1-ci, so the kernel sees the chunks LAST-first.
    ``dinit_ref`` doubles as the dS carry (revisited across ci): it is
    seeded with the final-state cotangent, updated with each chunk's
    d(state-in), and its content after the last grid step IS the
    initial-state gradient."""
    ci = pl.program_id(2)
    rc = nc - 1 - ci                                      # original chunk id

    @pl.when(ci == 0)
    def _init():
        dap_ref[...] = jnp.zeros_like(dap_ref)
        dinit_ref[0, 0] = dfin_ref[0, 0].astype(jnp.float32)

    x, dt, bmat, cmat = _load_chunk(x_ref, dt_ref, b_ref, c_ref, rc,
                                    cl=cl, S=S, mask_tail=mask_tail)
    a = a_ref[0].astype(jnp.float32)
    dy = dy_ref[0, :, 0].astype(jnp.float32)              # (cl, P)
    if mask_tail:
        pos = rc * cl + jax.lax.broadcasted_iota(jnp.int32, (cl, 1), 0)
        dy = jnp.where(pos < S, dy, 0.0)
    s_in = st_ref[0, 0, 0]                                # (P, N)
    ds_out = dinit_ref[0, 0]                              # (P, N)

    # ---- recompute the forward chunk quantities (cheap, chunk-local)
    da = dt * a
    cs = jnp.cumsum(da)
    seg = cs[:, None] - cs[None, :]
    tril = jax.lax.broadcasted_iota(jnp.int32, (cl, cl), 0) \
        >= jax.lax.broadcasted_iota(jnp.int32, (cl, cl), 1)
    decay = jnp.where(tril, jnp.exp(seg), 0.0)
    cb = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    att = cb * decay * dt[None, :]
    ecs = jnp.exp(cs)
    w = dt * jnp.exp(cs[-1] - cs)
    y_off = ecs[:, None] * jax.lax.dot_general(
        cmat, s_in, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)               # (cl, P)

    # ---- intra-chunk (y_diag = att @ x) cotangents
    datt = jax.lax.dot_general(dy, x, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)  # (cl,cl)
    dx = jax.lax.dot_general(att, dy, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)    # (cl, P)
    dcb = datt * decay * dt[None, :]
    db = jax.lax.dot_general(dcb, cmat, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)    # (cl, N)
    dc = jax.lax.dot_general(dcb, bmat, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)    # (cl, N)
    dseg = datt * cb * dt[None, :] * decay   # decay folds exp(seg) and tril
    dcs = jnp.sum(dseg, axis=1) - jnp.sum(dseg, axis=0)
    ddt_att = jnp.sum(datt * cb * decay, axis=0)          # (cl,) per column

    # ---- inter-chunk offset (y_off) cotangents
    dcs = dcs + jnp.sum(dy * y_off, axis=1)
    dc = dc + ecs[:, None] * jax.lax.dot_general(
        dy, s_in, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    # ---- state-update (S_out = e^{cs_end} S_in + Σ w_l x_l b_l^T)
    dSb = jax.lax.dot_general(bmat, ds_out, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)   # (cl, P)
    dS_x = jax.lax.dot_general(x, ds_out, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)  # (cl, N)
    dx = dx + w[:, None] * dSb
    db = db + w[:, None] * dS_x
    dw = jnp.sum(dS_x * bmat, axis=1)                     # (cl,)
    ddt_w = dw * jnp.exp(cs[-1] - cs)
    dcs = dcs - dw * w
    dcs_end = jnp.sum(dw * w) \
        + jnp.exp(cs[-1]) * jnp.sum(ds_out * s_in)
    dcs = dcs.at[-1].add(dcs_end)

    # ---- cumsum transpose + scalar-a partial
    dda = jnp.cumsum(dcs[::-1])[::-1]                     # Σ_{l>=t} dcs_l
    ddt = ddt_att + ddt_w + dda * a
    dap_ref[...] = dap_ref[...] + jnp.sum(dda * dt)[None, None]

    # ---- outputs + carried dS for the previous chunk
    dx_ref[0, :, 0] = dx
    ddt_ref[0, :, 0] = ddt
    dbh_ref[0, :, 0] = db
    dch_ref[0, :, 0] = dc
    dinit_ref[0, 0] = jnp.exp(cs[-1]) * ds_out + jax.lax.dot_general(
        dy * ecs[:, None], cmat, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def ssd_scan_bwd(x, dt, a, b, c, chunk_states, dy, dfinal, *,
                 chunk: int, interpret: bool = False):
    """Reversed-recurrence gradients from the per-chunk carried states.

    Returns (dx, ddt, da, db, dc, dinitial_state) in float32. db/dc are
    emitted per head (B,S,H,N) and reduced over each b/c group outside
    the kernel — an input-sized tensor, not a state history.
    """
    B, S, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    rep = H // G
    cl = min(chunk, S)
    nc = pl.cdiv(S, cl)

    rev = lambda ci: nc - 1 - ci
    outs = pl.pallas_call(
        functools.partial(_ssd_bwd_kernel, cl=cl, nc=nc, S=S,
                          mask_tail=(S % cl) != 0),
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, cl, 1, P), lambda bi, h, ci: (bi, rev(ci), h, 0)),
            pl.BlockSpec((1, cl, 1), lambda bi, h, ci: (bi, rev(ci), h)),
            pl.BlockSpec((1,), lambda bi, h, ci: (h,)),
            pl.BlockSpec((1, cl, 1, N),
                         lambda bi, h, ci: (bi, rev(ci), h * G // H, 0)),
            pl.BlockSpec((1, cl, 1, N),
                         lambda bi, h, ci: (bi, rev(ci), h * G // H, 0)),
            pl.BlockSpec((1, 1, 1, P, N),
                         lambda bi, h, ci: (bi, h, rev(ci), 0, 0)),
            pl.BlockSpec((1, cl, 1, P), lambda bi, h, ci: (bi, rev(ci), h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda bi, h, ci: (bi, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, cl, 1, P), lambda bi, h, ci: (bi, rev(ci), h, 0)),
            pl.BlockSpec((1, cl, 1), lambda bi, h, ci: (bi, rev(ci), h)),
            pl.BlockSpec((1, cl, 1, N), lambda bi, h, ci: (bi, rev(ci), h, 0)),
            pl.BlockSpec((1, cl, 1, N), lambda bi, h, ci: (bi, rev(ci), h, 0)),
            pl.BlockSpec((1, 1), lambda bi, h, ci: (bi, h)),
            pl.BlockSpec((1, 1, P, N), lambda bi, h, ci: (bi, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, P), jnp.float32),
            jax.ShapeDtypeStruct((B, S, H), jnp.float32),
            jax.ShapeDtypeStruct((B, S, H, N), jnp.float32),
            jax.ShapeDtypeStruct((B, S, H, N), jnp.float32),
            jax.ShapeDtypeStruct((B, H), jnp.float32),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, a, b, c, chunk_states, dy.astype(jnp.float32),
      dfinal.astype(jnp.float32))
    dx, ddt, dbh, dch, dap, dinit = outs
    da = jnp.sum(dap, axis=0)                             # (H,)
    db = jnp.sum(dbh.reshape(B, S, G, rep, N), axis=3)    # group-reduce
    dc = jnp.sum(dch.reshape(B, S, G, rep, N), axis=3)
    return dx, ddt, da, db, dc, dinit


# ------------------------------------------------------------ custom VJP --

@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7))
def ssd_scan_vjp(x, dt, a, b, c, initial_state, chunk, interpret=False):
    """ssd_scan with the reversed-recurrence Pallas backward (DESIGN.md §9).

    Residual contract: only the inputs (alive anyway) and the per-chunk
    carried states (nc snapshots) are saved — the backward re-streams the
    chunks in reverse, so the (S, P, N) state history never lands in HBM
    in either direction. Also the only *differentiable* kernel path: jax
    autodiff through the forward pallas_call raises (its JVP rule rejects
    ``pl.program_id``). ``initial_state`` must be a concrete (B,H,P,N)
    array (the ops wrapper materializes zeros for callers without one);
    its cotangent falls out of the dS carry for free."""
    return ssd_scan(x, dt, a, b, c, chunk=chunk, interpret=interpret,
                    initial_state=initial_state)


def _vjp_fwd(x, dt, a, b, c, initial_state, chunk, interpret):
    y, final, cstates = ssd_scan(x, dt, a, b, c, chunk=chunk,
                                 interpret=interpret,
                                 initial_state=initial_state,
                                 return_chunk_states=True)
    return (y, final), (x, dt, a, b, c, cstates)


def _vjp_bwd(chunk, interpret, res, g):
    x, dt, a, b, c, cstates = res
    dy, dfinal = g
    dx, ddt, da, db, dc, dinit = ssd_scan_bwd(
        x, dt, a, b, c, cstates, dy, dfinal, chunk=chunk,
        interpret=interpret)
    return (dx.astype(x.dtype), ddt.astype(dt.dtype), da.astype(a.dtype),
            db.astype(b.dtype), dc.astype(c.dtype), dinit)


ssd_scan_vjp.defvjp(_vjp_fwd, _vjp_bwd)
