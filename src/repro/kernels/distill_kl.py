"""Fused distillation-KL Pallas TPU kernel — the compute hot-spot of
DENSE stage 2 at LLM scale.

KL(softmax(t) ‖ softmax(s)) per row over very large vocabularies (up to
262 144). The naive jnp formulation materializes two (rows, V) float32
softmax/log-softmax intermediates in HBM (~2 * 4 * R * V bytes); this
kernel streams vocab blocks through VMEM with *online* log-sum-exp
accumulators for both distributions plus an online Σ e^{t−m}(t−s) term:

  KL = S/Z_t − lse_t + lse_s,  where  S = Σ_v e^{t_v − m_t}(t_v − s_v),
                                      Z_t = Σ_v e^{t_v − m_t}.

Accumulators live in revisited output blocks (index maps ignore the vocab
grid axis), the TPU-idiomatic analogue of CUDA shared-memory reductions.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -2.0 ** 30


def _kl_kernel(t_ref, s_ref, kl_ref, mt_ref, zt_ref, st_ref, ms_ref, zs_ref,
               *, nv: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        mt_ref[...] = jnp.full_like(mt_ref, NEG_INF)
        zt_ref[...] = jnp.zeros_like(zt_ref)
        st_ref[...] = jnp.zeros_like(st_ref)
        ms_ref[...] = jnp.full_like(ms_ref, NEG_INF)
        zs_ref[...] = jnp.zeros_like(zs_ref)

    t = t_ref[...].astype(jnp.float32)                    # (br, bv)
    s = s_ref[...].astype(jnp.float32)

    # online lse + weighted-diff for the teacher
    mt_prev, zt_prev, st_prev = mt_ref[...], zt_ref[...], st_ref[...]
    mt_cur = jnp.max(t, axis=1)
    mt_new = jnp.maximum(mt_prev, mt_cur)
    at = jnp.exp(mt_prev - mt_new)
    p = jnp.exp(t - mt_new[:, None])
    zt_ref[...] = zt_prev * at + jnp.sum(p, axis=1)
    st_ref[...] = st_prev * at + jnp.sum(p * (t - s), axis=1)
    mt_ref[...] = mt_new

    # online lse for the student
    ms_prev, zs_prev = ms_ref[...], zs_ref[...]
    ms_cur = jnp.max(s, axis=1)
    ms_new = jnp.maximum(ms_prev, ms_cur)
    as_ = jnp.exp(ms_prev - ms_new)
    zs_ref[...] = zs_prev * as_ + jnp.sum(jnp.exp(s - ms_new[:, None]), axis=1)
    ms_ref[...] = ms_new

    @pl.when(j == nv - 1)
    def _finalize():
        lse_t = mt_ref[...] + jnp.log(zt_ref[...])
        lse_s = ms_ref[...] + jnp.log(zs_ref[...])
        kl_ref[...] = st_ref[...] / zt_ref[...] - lse_t + lse_s


def distill_kl(teacher_logits, student_logits, *, block_rows: int = 256,
               block_v: int = 2048, interpret: bool = False):
    """(R, V) x (R, V) -> per-row KL (R,) float32."""
    R, V = teacher_logits.shape
    br = min(block_rows, R)
    bv = min(block_v, V)
    assert R % br == 0 and V % bv == 0, (R, br, V, bv)
    nr, nv = R // br, V // bv

    row_map = lambda i, j: (i,)
    out, *_ = pl.pallas_call(
        functools.partial(_kl_kernel, nv=nv),
        grid=(nr, nv),
        in_specs=[pl.BlockSpec((br, bv), lambda i, j: (i, j)),
                  pl.BlockSpec((br, bv), lambda i, j: (i, j))],
        out_specs=[pl.BlockSpec((br,), row_map)] * 6,
        out_shape=[jax.ShapeDtypeStruct((R,), jnp.float32)] * 6,
        interpret=interpret,
    )(teacher_logits, student_logits)
    return out
