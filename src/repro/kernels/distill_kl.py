"""Fused distillation-KL Pallas TPU kernel pair — the compute hot-spot
of DENSE stage 2 at LLM scale.

KL(softmax(t) ‖ softmax(s)) per row over very large vocabularies (up to
262 144). The naive jnp formulation materializes two (rows, V) float32
softmax/log-softmax intermediates in HBM (~2 * 4 * R * V bytes); the
*forward* kernel streams vocab blocks through VMEM with online
log-sum-exp accumulators for both distributions plus an online
Σ e^{t−m}(t−s) term:

  KL = S/Z_t − lse_t + lse_s,  where  S = Σ_v e^{t_v − m_t}(t_v − s_v),
                                      Z_t = Σ_v e^{t_v − m_t}.

Accumulators live in revisited output blocks (index maps ignore the vocab
grid axis), the TPU-idiomatic analogue of CUDA shared-memory reductions.

The *backward* is the repo's first custom-VJP kernel pair
(``distill_kl_vjp``; DESIGN.md §9): the forward persists only its per-row
accumulators (m_t, Z_t, S, m_s, Z_s — 5 float32 rows, ~20 bytes/row) as
residuals, and a second kernel re-streams the logit blocks to emit

  dL/ds = g ⊙ (softmax(s) − softmax(t))
  dL/dt = g ⊙ p ⊙ ((t − lse_t) − (s − lse_s) − KL),   p = softmax(t)

block-by-block — no (R, V) softmax intermediate ever lands in HBM in
either direction. ``with_teacher_grad=False`` skips the dL/dt stream for
teacher-is-constant call sites (DENSE's student step); the generator-side
losses (stage 1's adversarial L_div) keep it on.

Ragged shapes are handled in-kernel: the vocab tail block is masked to
NEG_INF before any arithmetic (Pallas pads out-of-range block reads with
undefined values), and out-of-range row lanes are dropped by the
out-of-bounds write semantics — no R % block_rows / V % block_v
restriction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -2.0 ** 30


def _mask_cols(t, s, j, bv: int, vocab: int):
    """Mask the out-of-vocab lanes of a (br, bv) block pair to NEG_INF.

    Must run before ANY arithmetic on the blocks: Pallas fills
    out-of-range block reads with undefined values (NaN in interpret
    mode), which would otherwise poison the row reductions. One iota +
    compare shared by both operands; it runs on every vocab block when
    V % bv != 0 (program_id is dynamic, so the tail block can't be
    special-cased at trace time) — VPU-trivial next to the block's
    exp/log work — and divisible vocabs skip it entirely via the static
    ``mask_tail`` flag."""
    col = j * bv + jax.lax.broadcasted_iota(jnp.int32, t.shape, 1)
    valid = col < vocab
    return jnp.where(valid, t, NEG_INF), jnp.where(valid, s, NEG_INF)


def _kl_fwd_kernel(t_ref, s_ref, kl_ref, mt_ref, zt_ref, st_ref, ms_ref,
                   zs_ref, *, nv: int, bv: int, vocab: int, mask_tail: bool):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        mt_ref[...] = jnp.full_like(mt_ref, NEG_INF)
        zt_ref[...] = jnp.zeros_like(zt_ref)
        st_ref[...] = jnp.zeros_like(st_ref)
        ms_ref[...] = jnp.full_like(ms_ref, NEG_INF)
        zs_ref[...] = jnp.zeros_like(zs_ref)

    t = t_ref[...].astype(jnp.float32)                    # (br, bv)
    s = s_ref[...].astype(jnp.float32)
    if mask_tail:
        t, s = _mask_cols(t, s, j, bv, vocab)

    # online lse + weighted-diff for the teacher
    mt_prev, zt_prev, st_prev = mt_ref[...], zt_ref[...], st_ref[...]
    mt_cur = jnp.max(t, axis=1)
    mt_new = jnp.maximum(mt_prev, mt_cur)
    at = jnp.exp(mt_prev - mt_new)
    p = jnp.exp(t - mt_new[:, None])
    zt_ref[...] = zt_prev * at + jnp.sum(p, axis=1)
    st_ref[...] = st_prev * at + jnp.sum(p * (t - s), axis=1)
    mt_ref[...] = mt_new

    # online lse for the student
    ms_prev, zs_prev = ms_ref[...], zs_ref[...]
    ms_cur = jnp.max(s, axis=1)
    ms_new = jnp.maximum(ms_prev, ms_cur)
    as_ = jnp.exp(ms_prev - ms_new)
    zs_ref[...] = zs_prev * as_ + jnp.sum(jnp.exp(s - ms_new[:, None]), axis=1)
    ms_ref[...] = ms_new

    @pl.when(j == nv - 1)
    def _finalize():
        lse_t = mt_ref[...] + jnp.log(zt_ref[...])
        lse_s = ms_ref[...] + jnp.log(zs_ref[...])
        kl_ref[...] = st_ref[...] / zt_ref[...] - lse_t + lse_s


def _blocking(R: int, V: int, block_rows: int, block_v: int):
    br = min(block_rows, R)
    bv = min(block_v, V)
    nr, nv = pl.cdiv(R, br), pl.cdiv(V, bv)
    return br, bv, nr, nv, (V % bv) != 0


def distill_kl(teacher_logits, student_logits, *, block_rows: int,
               block_v: int, interpret: bool = False,
               return_stats: bool = False):
    """(R, V) x (R, V) -> per-row KL (R,) float32.

    Any (R, V) is accepted: tail blocks are masked in-kernel (ragged
    vocab) and ragged row blocks rely on out-of-bounds writes being
    dropped. With ``return_stats=True`` additionally returns the per-row
    accumulators ``(m_t, Z_t, S, m_s, Z_s)`` the kernel already computed —
    the custom-VJP residuals (persisted instead of recomputed).
    """
    R, V = teacher_logits.shape
    br, bv, nr, nv, mask_tail = _blocking(R, V, block_rows, block_v)

    row_map = lambda i, j: (i,)
    kl, mt, zt, st, ms, zs = pl.pallas_call(
        functools.partial(_kl_fwd_kernel, nv=nv, bv=bv, vocab=V,
                          mask_tail=mask_tail),
        grid=(nr, nv),
        in_specs=[pl.BlockSpec((br, bv), lambda i, j: (i, j)),
                  pl.BlockSpec((br, bv), lambda i, j: (i, j))],
        out_specs=[pl.BlockSpec((br,), row_map)] * 6,
        out_shape=[jax.ShapeDtypeStruct((R,), jnp.float32)] * 6,
        interpret=interpret,
    )(teacher_logits, student_logits)
    if return_stats:
        return kl, (mt, zt, st, ms, zs)
    return kl


# ------------------------------------------------------- fused backward --

def _kl_bwd_kernel(t_ref, s_ref, lt_ref, ls_ref, kl_ref, g_ref, *out_refs,
                   bv: int, vocab: int, mask_tail: bool, with_dt: bool):
    """One (br, bv) block of the analytic KL gradients.

    Purely elementwise given the per-row statistics — no accumulators, so
    the grid is embarrassingly parallel (unlike the forward's sequential
    vocab sweep)."""
    j = pl.program_id(1)
    t = t_ref[...].astype(jnp.float32)
    s = s_ref[...].astype(jnp.float32)
    if mask_tail:
        t, s = _mask_cols(t, s, j, bv, vocab)
    lt = lt_ref[...][:, None]            # lse_t, (br, 1)
    ls = ls_ref[...][:, None]
    g = g_ref[...][:, None]
    p = jnp.exp(t - lt)                  # softmax(t) block
    q = jnp.exp(s - ls)                  # softmax(s) block
    ds_ref = out_refs[-1]
    ds_ref[...] = (g * (q - p)).astype(ds_ref.dtype)
    if with_dt:
        dt_ref = out_refs[0]
        kl = kl_ref[...][:, None]
        dt_ref[...] = (g * p * ((t - lt) - (s - ls) - kl)).astype(dt_ref.dtype)


def distill_kl_bwd(teacher_logits, student_logits, lse_t, lse_s, kl, g, *,
                   block_rows: int, block_v: int,
                   interpret: bool = False, with_teacher_grad: bool = True):
    """Stream the KL gradients from per-row stats: returns (dt, ds); dt is
    None when with_teacher_grad=False (the dL/dt stream is skipped
    entirely, not computed-and-zeroed)."""
    R, V = teacher_logits.shape
    br, bv, nr, nv, mask_tail = _blocking(R, V, block_rows, block_v)

    row_map = lambda i, j: (i,)
    blk_map = lambda i, j: (i, j)
    out_specs = [pl.BlockSpec((br, bv), blk_map)]
    out_shape = [jax.ShapeDtypeStruct((R, V), student_logits.dtype)]
    if with_teacher_grad:
        out_specs = [pl.BlockSpec((br, bv), blk_map)] + out_specs
        out_shape = [jax.ShapeDtypeStruct((R, V), teacher_logits.dtype)] \
            + out_shape
    outs = pl.pallas_call(
        functools.partial(_kl_bwd_kernel, bv=bv, vocab=V,
                          mask_tail=mask_tail, with_dt=with_teacher_grad),
        grid=(nr, nv),
        in_specs=[pl.BlockSpec((br, bv), blk_map),
                  pl.BlockSpec((br, bv), blk_map)]
        + [pl.BlockSpec((br,), row_map)] * 4,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(teacher_logits, student_logits, lse_t, lse_s, kl, g)
    if with_teacher_grad:
        return outs[0], outs[1]
    return None, outs[0]


# ------------------------------------------------------------ custom VJP --

@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5, 6, 7))
def distill_kl_vjp(teacher_logits, student_logits, block_rows, block_v,
                   interpret=False, with_teacher_grad=True,
                   bwd_rows=None, bwd_v=None):
    """distill_kl with the fused Pallas backward (DESIGN.md §9).

    Residual contract: only the inputs (alive anyway) and the per-row
    forward accumulators are saved — the backward re-streams the logit
    blocks, so neither pass materializes an (R, V) softmax in HBM.
    ``with_teacher_grad=False`` declares the teacher cotangent unused
    (e.g. stage 2's stop-gradient'd ensemble): the backward skips the
    dL/dt kernel stream and returns a zeros cotangent in its place —
    under jit (every repo call site) XLA dead-code-eliminates it when
    the teacher really is a non-differentiated input; an eager caller
    that actually consumes the teacher gradient should keep
    ``with_teacher_grad=True``.

    ``bwd_rows``/``bwd_v`` (None -> reuse the forward blocks) give the
    backward kernel its OWN block shapes: it streams up to 2x the
    forward's tensor traffic (dt and ds emission) with a different
    arithmetic intensity, so its best tile need not be the forward's —
    the registry/autotuner resolve them under the separate
    ``distill_kl_bwd`` kernel entry (configs/backend.py, DESIGN.md §11).
    """
    return distill_kl(teacher_logits, student_logits, block_rows=block_rows,
                      block_v=block_v, interpret=interpret)


def _vjp_fwd(t, s, block_rows, block_v, interpret, with_teacher_grad,
             bwd_rows, bwd_v):
    kl, (mt, zt, _st, ms, zs) = distill_kl(
        t, s, block_rows=block_rows, block_v=block_v, interpret=interpret,
        return_stats=True)
    # fold (m, Z) -> lse once per row; S already folded into kl
    return kl, (t, s, mt + jnp.log(zt), ms + jnp.log(zs), kl)


def _vjp_bwd(block_rows, block_v, interpret, with_teacher_grad,
             bwd_rows, bwd_v, res, g):
    t, s, lse_t, lse_s, kl = res
    dt, ds = distill_kl_bwd(t, s, lse_t, lse_s, kl,
                            g.astype(jnp.float32),
                            block_rows=bwd_rows if bwd_rows else block_rows,
                            block_v=bwd_v if bwd_v else block_v,
                            interpret=interpret,
                            with_teacher_grad=with_teacher_grad)
    if dt is None:
        # teacher declared constant by the caller: zeros cotangent — a
        # concrete array here (custom_vjp must return a full pytree), but
        # DCE'd by XLA under jit when the teacher is non-differentiated
        dt = jnp.zeros_like(t)
    return dt, ds


distill_kl_vjp.defvjp(_vjp_fwd, _vjp_bwd)
