"""Dirichlet non-IID partitioning (paper §3.1.2).

For each class k, sample p_k ~ Dir(alpha) over clients and allocate a
p_k^i fraction of class-k examples to client i. Small alpha => highly
skewed (some clients see few / no examples of a class).
"""
from __future__ import annotations

import numpy as np


def dirichlet_partition(labels: np.ndarray, n_clients: int, alpha: float,
                        seed: int = 0, min_size: int = 2):
    rng = np.random.default_rng(seed)
    n_classes = int(labels.max()) + 1
    while True:
        idx_per_client = [[] for _ in range(n_clients)]
        for k in range(n_classes):
            idx_k = np.where(labels == k)[0]
            rng.shuffle(idx_k)
            p = rng.dirichlet([alpha] * n_clients)
            cuts = (np.cumsum(p) * len(idx_k)).astype(int)[:-1]
            for i, part in enumerate(np.split(idx_k, cuts)):
                idx_per_client[i].extend(part.tolist())
        if min(len(ix) for ix in idx_per_client) >= min_size:
            break
    out = []
    for ix in idx_per_client:
        ix = np.asarray(ix)
        rng.shuffle(ix)
        out.append(ix)
    return out


def class_counts(labels: np.ndarray, idx: np.ndarray, n_classes: int):
    return np.bincount(labels[idx], minlength=n_classes)
