"""Dirichlet non-IID partitioning (paper §3.1.2).

For each class k, sample p_k ~ Dir(alpha) over clients and allocate a
p_k^i fraction of class-k examples to client i. Small alpha => highly
skewed (some clients see few / no examples of a class).
"""
from __future__ import annotations

import numpy as np


def dirichlet_partition(labels: np.ndarray, n_clients: int, alpha: float,
                        seed: int = 0, min_size: int = 2,
                        max_tries: int = 100):
    """Rejection-sample draws until every client holds >= ``min_size``
    examples. Fine at paper scale (m <= 20 succeeds within a try or
    two), but the all-clients-fed event becomes infeasibly improbable
    at m=1000 with small alpha — the old unbounded loop simply never
    terminated there. After ``max_tries`` rejections the LAST draw is
    deterministically repaired instead: each starving client takes
    examples from the back of the currently-largest client's list until
    it reaches the floor, preserving the draw's skew shape up to the
    minimum-size floor. Feasible regimes break out of the loop exactly
    as before (same rng consumption), so existing seeded partitions are
    unchanged."""
    if n_clients * min_size > len(labels):
        raise ValueError(
            f"cannot give {n_clients} clients >= {min_size} examples "
            f"each from {len(labels)} total")
    rng = np.random.default_rng(seed)
    n_classes = int(labels.max()) + 1
    for _ in range(max_tries):
        idx_per_client = [[] for _ in range(n_clients)]
        for k in range(n_classes):
            idx_k = np.where(labels == k)[0]
            rng.shuffle(idx_k)
            p = rng.dirichlet([alpha] * n_clients)
            cuts = (np.cumsum(p) * len(idx_k)).astype(int)[:-1]
            for i, part in enumerate(np.split(idx_k, cuts)):
                idx_per_client[i].extend(part.tolist())
        if min(len(ix) for ix in idx_per_client) >= min_size:
            break
    else:
        for i in range(n_clients):
            while len(idx_per_client[i]) < min_size:
                donor = max(range(n_clients),
                            key=lambda j: len(idx_per_client[j]))
                idx_per_client[i].append(idx_per_client[donor].pop())
    out = []
    for ix in idx_per_client:
        ix = np.asarray(ix)
        rng.shuffle(ix)
        out.append(ix)
    return out


def class_counts(labels: np.ndarray, idx: np.ndarray, n_classes: int):
    return np.bincount(labels[idx], minlength=n_classes)
