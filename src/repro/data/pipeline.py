"""Seeded minibatch iterators (numpy host-side; arrays are device_put by jit)."""
from __future__ import annotations

import numpy as np


def batches(x: np.ndarray, y: np.ndarray, batch_size: int, *, seed: int,
            epochs: int = 1, drop_last: bool = False):
    rng = np.random.default_rng(seed)
    n = len(y)
    for _ in range(epochs):
        perm = rng.permutation(n)
        end = n - (n % batch_size) if drop_last else n
        for i in range(0, end, batch_size):
            sel = perm[i:i + batch_size]
            yield x[sel], y[sel]


def lm_batches(tokens: np.ndarray, batch: int, seq: int, *, seed: int,
               steps: int):
    rng = np.random.default_rng(seed)
    max_start = len(tokens) - seq - 1
    for _ in range(steps):
        starts = rng.integers(0, max_start, batch)
        x = np.stack([tokens[s:s + seq] for s in starts])
        y = np.stack([tokens[s + 1:s + seq + 1] for s in starts])
        yield x, y
