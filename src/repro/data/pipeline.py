"""Seeded minibatch iterators and host-side batch *plans*.

``batches`` is the reference per-client iterator (numpy host-side; arrays
are device_put by jit). ``build_batch_plan`` precomputes the SAME seeded
index stream for a whole group of clients at once as one padded
``(m, steps, batch)`` tensor + validity mask, so the grouped local-update
engine (fl/client.local_update_grouped) can gather every minibatch on
device inside a single scanned program instead of slicing on the host
m x epochs x batches times. The two formulations consume identical
per-client permutation streams: ``np.random.default_rng(seed)`` with one
``permutation(n)`` call per epoch.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np


def batches(x: np.ndarray, y: np.ndarray, batch_size: int, *, seed: int,
            epochs: int = 1, drop_last: bool = False):
    rng = np.random.default_rng(seed)
    n = len(y)
    for _ in range(epochs):
        perm = rng.permutation(n)
        end = n - (n % batch_size) if drop_last else n
        for i in range(0, end, batch_size):
            sel = perm[i:i + batch_size]
            yield x[sel], y[sel]


@dataclass(frozen=True)
class BatchPlan:
    """Precomputed minibatch schedule for m clients training in lockstep.

    idx[k, s]  — sample indices into client k's (padded) shard for step s.
    mask[k, s] — True where the slot holds a real sample. A ragged final
                 batch is padded with index 0 and mask False; clients with
                 fewer batches per epoch than the group max get fully
                 masked steps (their params/opt state pass through
                 unchanged — see fl/client.make_grouped_local_update).
    """
    idx: np.ndarray            # (m, steps, batch) int32
    mask: np.ndarray           # (m, steps, batch) bool
    steps_per_epoch: int       # group max batches per epoch
    epochs: int
    batch_size: int

    @property
    def steps(self) -> int:
        return self.idx.shape[1]


def build_batch_plan(shard_sizes: Sequence[int], batch_size: int, *,
                     epochs: int, seeds: Sequence[int],
                     steps_per_epoch: int | None = None) -> BatchPlan:
    """Pad each client's shard schedule to the group's max batches/epoch
    and precompute every epoch's seeded permutation up front.

    Per client k the flattened (idx, mask) stream restricted to valid
    slots is EXACTLY the ``batches(..., seed=seeds[k], epochs=epochs)``
    index stream (drop_last=False), so grouped and per-client training
    consume identical data orderings. ``steps_per_epoch`` (>= every
    client's own batches/epoch) pads the plan to an externally imposed
    step count instead of this group's max — the chunked engine uses it
    to keep every chunk of a bucket on one compiled shape; the extra
    fully-masked steps pass params/opt state through untouched, so the
    trained result is invariant to it.
    """
    assert len(shard_sizes) == len(seeds)
    m = len(shard_sizes)
    nb = [-(-int(n) // batch_size) for n in shard_sizes]   # ceil
    nb_max = max(nb) if nb else 0
    if steps_per_epoch is not None:
        if steps_per_epoch < nb_max:
            raise ValueError(f"steps_per_epoch={steps_per_epoch} < group "
                             f"max batches/epoch {nb_max}")
        nb_max = int(steps_per_epoch)
    steps = epochs * nb_max
    idx = np.zeros((m, steps, batch_size), np.int32)
    mask = np.zeros((m, steps, batch_size), bool)
    for k, (n, seed) in enumerate(zip(shard_sizes, seeds)):
        rng = np.random.default_rng(seed)
        for e in range(epochs):
            perm = rng.permutation(int(n))
            for j in range(nb[k]):
                sel = perm[j * batch_size:(j + 1) * batch_size]
                s = e * nb_max + j
                idx[k, s, :len(sel)] = sel
                mask[k, s, :len(sel)] = True
    return BatchPlan(idx=idx, mask=mask, steps_per_epoch=nb_max,
                     epochs=epochs, batch_size=batch_size)


def bucket_members(shard_sizes: Sequence[int], batch_size: int,
                   mode: str = "off") -> list[tuple[int, ...]]:
    """Bin clients by batches/epoch before padding (DESIGN.md §13).

    Returns a partition of ``range(m)`` as member-index tuples, ordered
    by ascending bucket step count; members keep their original order
    within a bucket. Modes:

      off      — one bucket (today's single padded plan, bit-compatible)
      pow2     — bucket key = next power of two of ceil(n_k/batch): any
                 client wastes < 2x padded steps inside its bucket
      quantile — 4 quantile bins of the batches/epoch distribution:
                 adaptive to the actual skew (Dirichlet alpha <= 0.1
                 shards are long-tailed, where fixed pow2 edges can
                 leave the tail bucket wide)

    Bucketing NEVER changes a client's seeded minibatch stream — only
    the number of fully-masked padding steps appended to it (the stream
    identity is per-construction: ``build_batch_plan`` fills each
    client's row independently of its co-bucketed peers).
    """
    nb = [-(-int(n) // batch_size) for n in shard_sizes]
    m = len(nb)
    if mode == "off" or m <= 1:
        return [tuple(range(m))] if m else []
    if mode == "pow2":
        def key(b):
            p = 1
            while p < max(b, 1):
                p *= 2
            return p
        keys = [key(b) for b in nb]
    elif mode == "quantile":
        qs = np.quantile(np.asarray(nb, np.float64), [0.25, 0.5, 0.75])
        keys = list(np.searchsorted(qs, np.asarray(nb, np.float64),
                                    side="left"))
    else:
        raise ValueError(f"unknown plan_bucketing mode {mode!r}")
    buckets: dict = {}
    for i, k in enumerate(keys):
        buckets.setdefault(k, []).append(i)
    # order buckets by their actual max batches/epoch (ascending) so
    # compile shapes grow monotonically across a group's buckets
    return [tuple(buckets[k]) for k in
            sorted(buckets, key=lambda k: max(nb[i] for i in buckets[k]))]


def plan_step_waste(shard_sizes: Sequence[int], batch_size: int,
                    mode: str = "off") -> float:
    """Fraction of scheduled optimizer steps that are fully-masked
    padding under ``mode`` bucketing (epoch count cancels out). The
    benchmark scaling table reports this per mode; the m=1000
    Dirichlet-skew acceptance bound (>= 3x reduction) is tested in
    tests/test_scale.py."""
    nb = [-(-int(n) // batch_size) for n in shard_sizes]
    total = real = 0
    for members in bucket_members(shard_sizes, batch_size, mode):
        bmax = max(nb[i] for i in members)
        total += bmax * len(members)
        real += sum(nb[i] for i in members)
    return 1.0 - real / total if total else 0.0


def pad_shards(shards: Sequence[tuple], *,
               pad_to: int | None = None) -> tuple[np.ndarray, np.ndarray]:
    """Stack ragged per-client shards [(x_k, y_k), ...] into rectangular
    (m, max_n, ...) arrays, zero-padded past each client's n_k. Padding
    rows are never gathered by a BatchPlan (all plan indices < n_k).
    ``pad_to`` (>= max n_k) pads to an externally imposed width — the
    chunked engine passes its bucket's max so every chunk shares one
    compiled shape."""
    m = len(shards)
    max_n = max(len(y) for _, y in shards)
    if pad_to is not None:
        if pad_to < max_n:
            raise ValueError(f"pad_to={pad_to} < largest shard {max_n}")
        max_n = int(pad_to)
    x0, y0 = shards[0]
    xs = np.zeros((m, max_n, *x0.shape[1:]), x0.dtype)
    ys = np.zeros((m, max_n), y0.dtype)
    for k, (x, y) in enumerate(shards):
        xs[k, :len(y)] = x
        ys[k, :len(y)] = y
    return xs, ys


def lm_batches(tokens: np.ndarray, batch: int, seq: int, *, seed: int,
               steps: int):
    rng = np.random.default_rng(seed)
    max_start = len(tokens) - seq - 1
    for _ in range(steps):
        starts = rng.integers(0, max_start, batch)
        x = np.stack([tokens[s:s + seq] for s in starts])
        y = np.stack([tokens[s + 1:s + seq + 1] for s in starts])
        yield x, y
