"""Procedural datasets.

CIFAR/SVHN/... are not available offline; ``make_classification_data``
generates class-conditional structured images of the same tensor shapes so
the paper's *relative* claims can be validated (DESIGN.md §2): each class
has a fixed low-frequency template; samples are random shifts + per-sample
gains + Gaussian noise. Small CNNs reach high accuracy on the IID pooled
set, and Dirichlet splits make it properly non-IID per client.

``make_lm_data`` builds a Markov-chain token stream for LM examples.
"""
from __future__ import annotations

import numpy as np


def _class_templates(rng, num_classes, size, ch):
    """Smooth per-class templates: sum of a few random 2-D cosines."""
    ys, xs = np.mgrid[0:size, 0:size].astype(np.float32) / size
    t = np.zeros((num_classes, size, size, ch), np.float32)
    for c in range(num_classes):
        for _ in range(4):
            fx, fy = rng.integers(1, 5, 2)
            phase = rng.uniform(0, 2 * np.pi, ch)
            amp = rng.uniform(0.5, 1.0, ch)
            for k in range(ch):
                t[c, :, :, k] += amp[k] * np.cos(
                    2 * np.pi * (fx * xs + fy * ys) + phase[k])
    t /= np.abs(t).max(axis=(1, 2, 3), keepdims=True)
    return t


def make_classification_data(seed: int, *, num_classes=10, size=32, ch=3,
                             train_per_class=512, test_per_class=128,
                             noise=0.35):
    """Returns dict(train=(x,y), test=(x,y)) with x in [-1, 1], NHWC."""
    rng = np.random.default_rng(seed)
    templates = _class_templates(rng, num_classes, size, ch)

    def sample(n_per_class):
        xs, ys = [], []
        for c in range(num_classes):
            shifts = rng.integers(-size // 8, size // 8 + 1, (n_per_class, 2))
            gains = rng.uniform(0.7, 1.3, (n_per_class, 1, 1, 1)).astype(np.float32)
            base = np.stack([np.roll(templates[c], tuple(s), axis=(0, 1))
                             for s in shifts])
            x = base * gains + noise * rng.standard_normal(
                base.shape).astype(np.float32)
            xs.append(np.clip(x, -1, 1))
            ys.append(np.full((n_per_class,), c, np.int32))
        x = np.concatenate(xs)
        y = np.concatenate(ys)
        perm = rng.permutation(len(y))
        return x[perm].astype(np.float32), y[perm]

    return {"train": sample(train_per_class), "test": sample(test_per_class)}


def make_lm_data(seed: int, *, vocab=512, n_tokens=200_000, order_bias=0.9):
    """Markov token stream: each token strongly predicts a successor band —
    learnable structure for LM smoke training."""
    rng = np.random.default_rng(seed)
    succ = rng.integers(0, vocab, vocab)
    toks = np.empty((n_tokens,), np.int32)
    toks[0] = rng.integers(vocab)
    jumps = rng.random(n_tokens) > order_bias
    rand = rng.integers(0, vocab, n_tokens)
    for i in range(1, n_tokens):
        toks[i] = rand[i] if jumps[i] else (succ[toks[i - 1]] + i % 3) % vocab
    return toks
