from repro.data.synthetic import make_classification_data, make_lm_data
from repro.data.partition import dirichlet_partition, class_counts
from repro.data.pipeline import batches, lm_batches

__all__ = ["make_classification_data", "make_lm_data",
           "dirichlet_partition", "class_counts", "batches", "lm_batches"]
