"""Parameter / activation / cache partitioning rules.

Megatron-style 2D layout on (data|pod, model):
  - embeddings + tied LM head: vocab sharded over `model`
  - attention QKV/O: head-sharded over `model` *iff* both n_heads and
    n_kv_heads divide the model-axis size; otherwise replicated (gemma3 has
    8 q / 4 kv heads, phi3 40/10, llama 24/8 — none divide 16). Replicated
    attention keeps the lowering correct; the memory cost is carried by
    ZeRO-1 optimizer-state sharding over `data` (head-padding to a
    shardable count is a §Perf hillclimb, see EXPERIMENTS.md).
  - MLP up/gate column-, down row-sharded over `model`
  - MoE experts expert-parallel over `model` (E % model == 0 for both
    deepseek configs); router replicated
  - Mamba2 z/x/dt projections head-sharded over `model` when the head
    count divides (zamba2: 112 heads), else replicated (mamba2-130m: 24);
    B/C group projections always replicated (G=1 shared state)
  - optimizer moments: parameter spec + largest still-replicated dim
    sharded over `data` (ZeRO-1)
Batch dims shard over (pod, data); for global_batch=1 long-context decode
the KV-cache *sequence* dim shards over `data` instead.
"""
from __future__ import annotations

import numpy as np
import jax
from jax.sharding import PartitionSpec as P, NamedSharding

from repro.configs.base import ArchConfig

MP = "model"


def _axis(mesh, name):
    return mesh.shape[name] if name in mesh.axis_names else 1


def attn_sharded(cfg: ArchConfig, mesh) -> bool:
    m = _axis(mesh, MP)
    if cfg.kv_lora_rank:
        return cfg.n_heads % m == 0
    return cfg.n_heads % m == 0 and cfg.n_kv_heads % m == 0


def ssm_sharded(cfg: ArchConfig, mesh) -> bool:
    m = _axis(mesh, MP)
    return cfg.ssm_state > 0 and cfg.n_ssm_heads % m == 0


def param_specs(cfg: ArchConfig, params_shape, mesh):
    """PartitionSpec tree matching an (abstract) params tree."""
    a_sh = attn_sharded(cfg, mesh)
    s_sh = ssm_sharded(cfg, mesh)
    m = _axis(mesh, MP)

    def rule(path_keys, leaf):
        keys = [getattr(pk, "key", str(pk)) for pk in path_keys]
        path = "/".join(keys)
        nd = len(leaf.shape)

        def pad(spec):
            return P(*([None] * (nd - len(spec)) + list(spec)))

        if path.endswith("embed/table"):
            return pad([MP, None]) if leaf.shape[-2] % m == 0 else pad([None, None])
        # --- MoE experts (raw (E, d, f) arrays under .../moe/) ---
        if "/moe/" in path or path.startswith("moe/"):
            if keys[-1] in ("gate", "up", "down") and "shared" not in keys:
                return pad([MP, None, None])
            if "router" in keys:
                return pad([None] * min(nd, 2))
            if "shared" in keys:
                if keys[-2] in ("gate", "up"):
                    return pad([None, MP])
                if keys[-2] == "down":
                    return pad([MP, None])
                return pad([None])
        # --- attention ---
        if any(k in ("attn", "xattn") for k in keys):
            if not a_sh or "xattn" in keys:
                return pad([None] * min(nd, 2))
            last2 = keys[-2] if len(keys) >= 2 else ""
            if last2 in ("wq", "wk", "wv", "wq_b", "wkv_b"):
                return pad([None, MP]) if keys[-1] == "w" else pad([MP])
            if last2 == "wo":
                return pad([MP, None]) if keys[-1] == "w" else pad([None])
            return pad([None] * min(nd, 2))       # wq_a, wkv_a, norms, gate
        # --- dense MLPs ---
        if "mlp" in keys and keys[-1] == "w":
            if keys[-2] in ("gate", "up"):
                return pad([None, MP])
            if keys[-2] == "down":
                return pad([MP, None])
        if keys[-1] == "mlp_gate":
            return P()
        # --- mamba ---
        if "mamba" in keys:
            if not s_sh:
                return pad([None] * min(nd, 2))
            last2 = keys[-2] if len(keys) >= 2 else ""
            if last2 in ("in_z", "in_x", "in_dt") and keys[-1] == "w":
                return pad([None, MP])
            if last2 in ("in_z", "in_x", "in_dt") and keys[-1] == "b":
                return pad([MP])
            if last2 == "conv_x":
                return pad([None, MP]) if keys[-1] == "w" else pad([MP])
            if last2 == "out_proj" and keys[-1] == "w":
                return pad([MP, None])
            if keys[-1] in ("a_log", "dt_bias", "d_skip"):
                return pad([MP])
            if last2 == "norm":
                return pad([MP])
            return pad([None] * min(nd, 2))       # in_bc, conv_bc
        return pad([None] * min(nd, 2))           # norms, biases, misc

    return jax.tree_util.tree_map_with_path(rule, params_shape)


def zero1_specs(param_specs_tree, params_shape, mesh, *,
                min_size: int = 1 << 16):
    """Optimizer-moment specs: param spec + shard the largest
    still-replicated dim over `data` (ZeRO-1)."""
    dp = _axis(mesh, "data")

    def rule(spec, leaf):
        shape = leaf.shape
        if int(np.prod(shape)) < min_size or dp == 1:
            return spec
        cur = list(spec) + [None] * (len(shape) - len(spec))
        order = sorted(range(len(shape)), key=lambda i: -shape[i])
        for i in order:
            if cur[i] is None and shape[i] % dp == 0 and shape[i] >= dp:
                cur[i] = "data"
                return P(*cur)
        return spec

    return jax.tree_util.tree_map(rule, param_specs_tree, params_shape)


def batch_specs(mesh, batch: int):
    """Token-batch sharding over every data-parallel axis that divides."""
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    size = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    if axes and batch % size == 0:
        return tuple(axes)
    if "data" in mesh.axis_names and batch % mesh.shape["data"] == 0:
        return ("data",)
    return None


def cache_specs(cfg: ArchConfig, cache_shape, mesh, *, batch: int,
                seq_shard_replicated_attn: bool = True):
    """Spec tree for a decode KV/SSM cache (matches init_cache layout).

    seq_shard_replicated_attn (§Perf-3): when attention weights are
    replicated (head counts don't divide the model axis), shard the cache
    *sequence* dim over `model` instead of holding a full replica per
    device — decode then reads 1/model of the cache per chip and XLA
    realizes the softmax over the sharded axis with scalar-sized
    collectives (flash-decode style). False reproduces the baseline.
    """
    a_sh = attn_sharded(cfg, mesh)
    s_sh = ssm_sharded(cfg, mesh)
    bspec = batch_specs(mesh, batch)
    # global_batch=1 long-context: shard the sequence dim over `data`
    seq_spec = "data" if (bspec is None and "data" in mesh.axis_names) else None

    def rule(path_keys, leaf):
        keys = [getattr(pk, "key", str(pk)) for pk in path_keys]
        nd = len(leaf.shape)

        def pad(base):
            return P(*([None] * (nd - len(base)) + base))

        last = keys[-1]
        if last in ("k", "v"):            # (B, S, kh, hd)
            if a_sh:
                return pad([bspec, seq_spec, MP, None])
            if seq_shard_replicated_attn:
                s_axes = (seq_spec, MP) if seq_spec else MP
                return pad([bspec, s_axes, None, None])
            return pad([bspec, seq_spec, None, None])
        if last == "c_kv" or last == "k_rope":   # (B, S, r)
            return pad([bspec, seq_spec, None])
        if last == "ssm":                 # (B, H, P, N)
            return pad([bspec, MP if s_sh else None, None, None])
        if last == "conv_x":              # (B, K-1, di)
            return pad([bspec, None, MP if s_sh else None])
        if last == "conv_bc":
            return pad([bspec, None, None])
        return pad([None] * nd)

    return jax.tree_util.tree_map_with_path(rule, cache_shape)


def to_named(tree, mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))
