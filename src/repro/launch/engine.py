"""Request-level continuous-batching serving engine (DESIGN.md §12).

``ServeEngine`` replaces the fixed-batch ``serve()`` monolith with the
API production traffic needs: callers ``submit()`` individual requests
(ragged prompt/gen lengths, any arrival order), ``step()`` advances the
whole engine one scheduler iteration, ``poll()``/``drain()`` collect
per-request results.

Two execution modes:

  * ``"paged"`` (default where supported) — continuous batching over the
    block-pool cache (launch/paging.py). One jitted decode step advances
    EVERY running request at once through ``T.forward_paged`` /
    ``kernels.paged_attention``; admission runs an exact-length dense
    prefill per request and scatters the filled cache into the pool, so
    a new request joins the running batch without touching the others
    (the SSM prefill→decode handoff is exact by PR 5's
    ``initial_state`` split≡full guarantee).
  * ``"dense"`` — the sequential reference: one request at a time,
    batch-1 dense cache, the PR-scope oracle for paged-vs-dense token
    equivalence and the fallback for families the paged layout doesn't
    cover (moe's MLA latent cache, vlm's cross-attention stream,
    sliding-window patterns, model-parallel meshes).

Scheduling policy (deliberately simple, fully deterministic): FIFO
admission; a request is admitted the moment a scheduler slot AND its
whole block budget ``ceil((prompt+max_new)/page)`` are free — blocks are
granted for the request's lifetime up front, so decode can never
deadlock mid-flight; completion (``max_new`` tokens) releases the slot
and blocks immediately. Head-of-line blocking is accepted: a queued
request never overtakes an earlier one.

Sampling is decoupled from batch composition: greedy is host-side
argmax over f32 logits; stochastic sampling draws from
``fold_in(fold_in(k_sample, request_id), token_index)`` so a request's
token stream is identical whatever else shares its decode batch — this
is what makes continuous ≡ sequential testable (and is the fix for the
old serve.py reusing one key for init/prompts/sampling).
"""
from __future__ import annotations

import contextlib
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import backend as B
from repro.configs.base import ArchConfig
from repro.launch import paging as PG
from repro.launch import steps as ST
from repro.models import transformer as T

supports_paged = PG.supports_paged


def engine_keys(seed: int):
    """The serving PRNG streams: (init, prompts, sampling). One split up
    front — init_model, synthetic-prompt draws and token sampling must
    never share a key (the old serve.py reused one for all three)."""
    return tuple(jax.random.split(jax.random.PRNGKey(seed), 3))


@dataclasses.dataclass
class _Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    temperature: float | None        # None -> greedy
    tokens: list = dataclasses.field(default_factory=list)
    slot: int = -1
    blocks: tuple = ()
    status: str = "queued"           # queued | running | done
    t_submit: float = 0.0
    t_done: float = 0.0


class ServeEngine:
    """See module docstring. ``max_len`` bounds ``prompt + max_new`` per
    request; ``max_reqs`` is the concurrent-slot count; ``n_blocks``
    defaults to exactly enough for ``max_reqs`` worst-case requests plus
    the reserved null block (size the pool smaller to exercise
    exhaustion/queueing)."""

    def __init__(self, cfg: ArchConfig, params=None, policy=None, *,
                 mesh=None, max_reqs: int = 4, max_len: int = 256,
                 n_blocks: int | None = None, page: int | None = None,
                 mode: str | None = None, seed: int = 0):
        self.cfg = cfg
        self.mesh = mesh
        self.policy = B.resolve_exec_policy(policy)
        k_init, _, self._k_sample = engine_keys(seed)
        self.params = T.init_model(k_init, cfg) if params is None else params
        self.max_reqs, self.max_len = int(max_reqs), int(max_len)

        model_par = (mesh is not None
                     and dict(zip(mesh.axis_names, mesh.devices.shape))
                     .get("model", 1) > 1)
        if mode is None:
            mode = "paged" if supports_paged(cfg) and not model_par \
                else "dense"
        if mode == "paged" and (not supports_paged(cfg) or model_par):
            raise ValueError(
                f"paged mode unsupported here (family={cfg.family!r}, "
                f"sliding_window={cfg.sliding_window}, "
                f"kv_lora_rank={cfg.kv_lora_rank}, "
                f"model_parallel={model_par}); use mode='dense'")
        if mode not in ("paged", "dense"):
            raise ValueError(f"unknown mode {mode!r}")
        self.mode = mode

        self._queue: list[_Request] = []
        self._reqs: dict[int, _Request] = {}
        self._next_rid = 0
        self.stats = {"prefill_s": 0.0, "decode_s": 0.0,
                      "decode_steps": 0, "generated": 0}

        if mode == "paged":
            self.page = page if page is not None \
                else PG.page_size(self.policy, self.max_len)
            self.page = max(1, min(int(self.page), self.max_len))
            self.n_pages = -(-self.max_len // self.page)
            if n_blocks is None:
                n_blocks = 1 + self.max_reqs * self.n_pages
            self.allocator = PG.BlockAllocator(n_blocks)
            with self._ctx():
                self._pools = PG.init_paged_cache(
                    cfg, max_reqs=self.max_reqs, n_blocks=n_blocks,
                    page=self.page)
                self._bt = jnp.zeros((self.max_reqs, self.n_pages),
                                     jnp.int32)
            self._slots: list[_Request | None] = [None] * self.max_reqs
            self._seq = np.zeros((self.max_reqs,), np.int32)
            self._cur = np.zeros((self.max_reqs,), np.int32)
            self._admit_cache: dict[int, object] = {}

            def decode_step(params, pools, bt, tokens, positions):
                logits, new_pools = T.forward_paged(
                    params, cfg, tokens=tokens, positions=positions,
                    cache=pools, block_tables=bt)
                return logits[:, -1].astype(jnp.float32), new_pools

            self._decode = jax.jit(decode_step, donate_argnums=(1,))
        else:
            # uniform signatures: vision is always a keyword (None for
            # text-only families) — no positional special-casing
            self._prefill = jax.jit(ST.make_prefill_step(cfg, mesh),
                                    donate_argnums=(1,))
            self._dec = jax.jit(ST.make_serve_step(cfg, mesh),
                                donate_argnums=(1,))
            self._vision = (jnp.zeros((1, cfg.n_patches, cfg.vision_dim))
                            if cfg.family == "vlm" else None)

    # ------------------------------------------------------------- API --

    def submit(self, prompt, max_new: int = 16, sampling=None) -> int:
        """Queue a request; returns its id. ``sampling``: None/{} →
        greedy argmax, ``{"temperature": t}`` → categorical at t."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if max_new < 1:
            raise ValueError("max_new must be >= 1")
        if prompt.size + max_new > self.max_len:
            raise ValueError(
                f"prompt ({prompt.size}) + max_new ({max_new}) exceeds "
                f"engine max_len ({self.max_len})")
        temperature = None
        if sampling:
            temperature = float(sampling.get("temperature", 1.0))
        rid = self._next_rid
        self._next_rid += 1
        req = _Request(rid, prompt, int(max_new), temperature,
                       t_submit=time.perf_counter())
        self._reqs[rid] = req
        self._queue.append(req)
        return rid

    def step(self) -> int:
        """One scheduler iteration. Paged: admit whatever fits, then one
        fused decode step for every running slot. Dense: run the oldest
        queued request to completion. Returns live (queued + running)
        request count."""
        if self.mode == "paged":
            admitted = self._admit()
            if (not admitted and self._queue
                    and all(s is None for s in self._slots)):
                req = self._queue[0]
                raise RuntimeError(
                    f"request {req.rid} needs "
                    f"{PG.blocks_needed(len(req.prompt), req.max_new, self.page)} "
                    f"blocks but the idle pool has only "
                    f"{self.allocator.n_free} — pool too small for this "
                    "request")
            self._decode_once()
        else:
            self._run_one_dense()
        return sum(1 for r in self._reqs.values() if r.status != "done")

    def poll(self, rid: int) -> dict:
        r = self._reqs[rid]
        out = {"status": r.status, "tokens": list(r.tokens)}
        if r.status == "done":
            out["latency_s"] = r.t_done - r.t_submit
        return out

    def drain(self, max_steps: int | None = None) -> dict:
        """step() until every submitted request completes; returns
        {rid: np.ndarray of generated tokens}."""
        if max_steps is None:
            max_steps = 4 * sum(r.max_new + 2 for r in self._reqs.values()
                                if r.status != "done") + 16
        steps = 0
        while any(r.status != "done" for r in self._reqs.values()):
            self.step()
            steps += 1
            if steps > max_steps:
                raise RuntimeError(f"drain exceeded {max_steps} steps — "
                                   "scheduler stuck")
        return {r.rid: np.asarray(r.tokens, np.int32)
                for r in self._reqs.values()}

    # ------------------------------------------------------ internals --

    def _ctx(self):
        return self.mesh if self.mesh is not None \
            else contextlib.nullcontext()

    def _sample(self, req: _Request, logits_row: np.ndarray) -> int:
        self.stats["generated"] += 1
        if req.temperature is None:
            return int(np.argmax(logits_row))
        k = jax.random.fold_in(
            jax.random.fold_in(self._k_sample, req.rid), len(req.tokens))
        return int(jax.random.categorical(
            k, jnp.asarray(logits_row) / req.temperature))

    def _finish(self, req: _Request):
        req.status = "done"
        req.t_done = time.perf_counter()
        if req.slot >= 0:
            slot = req.slot
            self._slots[slot] = None
            self._seq[slot] = 0
            self._cur[slot] = 0
            # point the freed slot's table back at the null block so its
            # masked decode writes stop touching the released blocks
            self._bt = self._bt.at[slot].set(
                jnp.zeros((self.n_pages,), jnp.int32))
            self.allocator.release(req.blocks)
            req.slot = -1

    # paged mode ----------------------------------------------------------

    def _admit_fn(self, p: int):
        fn = self._admit_cache.get(p)
        if fn is None:
            cfg = self.cfg

            def admit(params, pools, bt, prompt, slot, row):
                # exact-length prefill: no padding, because pad tokens
                # would advance the SSM recurrence and shift the last-
                # token logits; one jit cache entry per prompt length
                cache = T.init_cache(cfg, 1, p)
                logits, filled, _ = T.forward(
                    params, cfg, tokens=prompt,
                    positions=jnp.arange(p, dtype=jnp.int32), cache=cache,
                    cache_pos=jnp.int32(0), vision=None, remat=False)
                pools, bt = PG.scatter_prefill(cfg, pools, bt, filled,
                                               slot, row)
                return logits[:, -1].astype(jnp.float32), pools, bt

            fn = jax.jit(admit, donate_argnums=(1, 2))
            self._admit_cache[p] = fn
        return fn

    def _admit(self) -> int:
        admitted = 0
        while self._queue:
            req = self._queue[0]
            slot = next((i for i, s in enumerate(self._slots)
                         if s is None), None)
            if slot is None:
                break
            need = PG.blocks_needed(len(req.prompt), req.max_new, self.page)
            blocks = self.allocator.alloc(need)
            if blocks is None:
                break                    # pool exhausted: wait, FIFO holds
            self._queue.pop(0)
            t0 = time.perf_counter()
            row = np.zeros((self.n_pages,), np.int32)
            row[:need] = blocks
            p = len(req.prompt)
            with self._ctx():
                logits, self._pools, self._bt = self._admit_fn(p)(
                    self.params, self._pools, self._bt,
                    jnp.asarray(req.prompt)[None], jnp.int32(slot),
                    jnp.asarray(row))
                logits = np.asarray(logits[0])
            req.slot, req.blocks, req.status = slot, tuple(blocks), "running"
            self._slots[slot] = req
            self._seq[slot] = p
            tok = self._sample(req, logits)
            req.tokens.append(tok)
            self._cur[slot] = tok
            self.stats["prefill_s"] += time.perf_counter() - t0
            admitted += 1
            if len(req.tokens) >= req.max_new:
                self._finish(req)
        return admitted

    def _decode_once(self):
        if all(s is None for s in self._slots):
            return
        t0 = time.perf_counter()
        with self._ctx():
            logits, self._pools = self._decode(
                self.params, self._pools, self._bt,
                jnp.asarray(self._cur)[:, None], jnp.asarray(self._seq))
            logits = np.asarray(logits)
        for slot, req in enumerate(self._slots):
            if req is None:
                continue
            self._seq[slot] += 1
            tok = self._sample(req, logits[slot])
            req.tokens.append(tok)
            self._cur[slot] = tok
            if len(req.tokens) >= req.max_new:
                self._finish(req)
        self.stats["decode_s"] += time.perf_counter() - t0
        self.stats["decode_steps"] += 1

    # dense (sequential reference / fallback) mode ------------------------

    def _run_one_dense(self):
        if not self._queue:
            return
        req = self._queue.pop(0)
        req.status = "running"
        p = len(req.prompt)
        t0 = time.perf_counter()
        with self._ctx():
            cache = T.init_cache(self.cfg, 1, p + req.max_new)
            logits, cache = self._prefill(self.params, cache,
                                          jnp.asarray(req.prompt)[None],
                                          vision=self._vision)
            first = np.asarray(logits[0, -1], np.float32)
        req.tokens.append(self._sample(req, first))
        self.stats["prefill_s"] += time.perf_counter() - t0
        t0 = time.perf_counter()
        with self._ctx():
            for i in range(req.max_new - 1):
                logits, cache = self._dec(
                    self.params, cache,
                    jnp.asarray([[req.tokens[-1]]], jnp.int32),
                    jnp.int32(p + i), vision=self._vision)
                req.tokens.append(
                    self._sample(req, np.asarray(logits[0, -1], np.float32)))
        self.stats["decode_s"] += time.perf_counter() - t0
        self.stats["decode_steps"] += max(0, req.max_new - 1)
        self._finish(req)
