"""Serving driver: thin batch-style wrapper + CLI over ServeEngine.

The engine (launch/engine.py) owns the real API — ``submit``/``step``/
``poll``/``drain`` over a paged block-pool cache with continuous
batching (DESIGN.md §12). This module keeps the historical fixed-batch
entry point as a compat wrapper: ``serve(arch, batch=..., ...)`` submits
``batch`` identical-length synthetic prompts and drains, returning the
same ``(tokens, stats)`` pair as before.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b --smoke \
      --batch 4 --prompt-len 64 --gen 32 [--mode paged|dense]
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs.base import get_config, get_smoke_config
from repro.launch.engine import ServeEngine, engine_keys
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T


def serve(arch: str, *, batch: int, prompt_len: int, gen: int,
          smoke: bool = True, model_parallel: int = 1, seed: int = 0,
          params=None, greedy: bool = True, temperature: float = 1.0,
          mode: str | None = None):
    """Compat wrapper: ``batch`` synthetic requests through a
    ServeEngine. Returns (tokens (batch, gen) int32, stats with
    prefill_s / decode_s / tok_per_s — the historical keys)."""
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    mesh = make_host_mesh(model_parallel)
    # one split up front: init / prompts / sampling never share a key
    # (the engine derives the sampling stream from the same seed)
    k_init, k_prompt, _ = engine_keys(seed)
    if params is None:
        params = T.init_model(k_init, cfg)
    prompts = np.asarray(jax.random.randint(
        k_prompt, (batch, prompt_len), 0, cfg.vocab_size), np.int32)

    eng = ServeEngine(cfg, params, mesh=mesh, max_reqs=batch,
                      max_len=prompt_len + gen, mode=mode, seed=seed)
    sampling = None if greedy else {"temperature": temperature}
    rids = [eng.submit(prompts[i], max_new=gen, sampling=sampling)
            for i in range(batch)]
    results = eng.drain()
    tokens = np.stack([results[r] for r in rids])
    decode_s = eng.stats["decode_s"]
    return tokens, {"prefill_s": eng.stats["prefill_s"],
                    "decode_s": decode_s,
                    "tok_per_s": batch * gen / max(decode_s, 1e-9)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--mode", choices=["paged", "dense"], default=None,
                    help="engine mode (default: paged where supported)")
    a = ap.parse_args()
    toks, stats = serve(a.arch, batch=a.batch, prompt_len=a.prompt_len,
                        gen=a.gen, smoke=a.smoke,
                        model_parallel=a.model_parallel, mode=a.mode)
    print("generated shape:", toks.shape)
    print({k: round(v, 3) for k, v in stats.items()})


if __name__ == "__main__":
    main()
