"""Batched serving driver: prefill + decode loop with KV/SSM caches.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b --smoke \
      --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, get_smoke_config
from repro.launch.mesh import make_host_mesh
from repro.launch import steps as ST
from repro.models import transformer as T


def serve(arch: str, *, batch: int, prompt_len: int, gen: int,
          smoke: bool = True, model_parallel: int = 1, seed: int = 0,
          params=None, greedy: bool = True, temperature: float = 1.0):
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    mesh = make_host_mesh(model_parallel)
    key = jax.random.PRNGKey(seed)
    if params is None:
        params = T.init_model(key, cfg)
    vision = (jnp.zeros((batch, cfg.n_patches, cfg.vision_dim))
              if cfg.family == "vlm" else None)

    max_len = prompt_len + gen
    prefill = jax.jit(ST.make_prefill_step(cfg, mesh), donate_argnums=(1,))
    decode = jax.jit(ST.make_serve_step(cfg, mesh), donate_argnums=(1,))

    prompts = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab_size)
    with mesh:
        cache = T.init_cache(cfg, batch, max_len)
        t0 = time.time()
        logits, cache = prefill(params, cache, prompts, vision) \
            if vision is not None else prefill(params, cache, prompts)
        t_prefill = time.time() - t0
        out = []
        tok = jnp.argmax(logits[:, -1], -1)[:, None]
        t0 = time.time()
        for i in range(gen):
            out.append(np.asarray(tok))
            pos = jnp.int32(prompt_len + i)
            args = (params, cache, tok, pos) + ((vision,) if vision is not None
                                                else ())
            logits, cache = decode(*args)
            lg = logits[:, -1].astype(jnp.float32)
            if greedy:
                tok = jnp.argmax(lg, -1)[:, None]
            else:
                key, k2 = jax.random.split(key)
                tok = jax.random.categorical(k2, lg / temperature)[:, None]
        t_decode = time.time() - t0
    tokens = np.concatenate(out, axis=1)
    return tokens, {"prefill_s": t_prefill, "decode_s": t_decode,
                    "tok_per_s": batch * gen / max(t_decode, 1e-9)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--model-parallel", type=int, default=1)
    a = ap.parse_args()
    toks, stats = serve(a.arch, batch=a.batch, prompt_len=a.prompt_len,
                        gen=a.gen, smoke=a.smoke,
                        model_parallel=a.model_parallel)
    print("generated shape:", toks.shape)
    print({k: round(v, 3) for k, v in stats.items()})


if __name__ == "__main__":
    main()
