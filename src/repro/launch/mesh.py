"""Production mesh construction (TPU v5e pods).

Single pod: 16 x 16 = 256 chips, axes (data, model).
Two pods:   2 x 16 x 16 = 512 chips, axes (pod, data, model); the pod axis
carries pure data parallelism for training and doubles as the DENSE
*ensemble* axis in the server loop (DESIGN.md §6).

The federation-scale analogue is ``make_client_mesh``: a
("clients", "data") mesh whose leading axis shards the grouped engine's
stacked client dim (fl/sharding.py owns the specs/placement vocabulary;
DESIGN.md §8).

Defined as functions (never module-level constants) so importing this
module does not touch jax device state.
"""
from __future__ import annotations

import jax
import numpy as np

# TPU v5e roofline constants (per chip)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link


def axis_types_kw(n_axes: int) -> dict:
    """{"axis_types": (Auto,)*n} on jax versions that have AxisType
    (>=0.5), {} on older ones where Auto is the only behaviour anyway."""
    at = getattr(jax.sharding, "AxisType", None)
    if at is None:
        return {}
    return {"axis_types": (at.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **axis_types_kw(len(axes)))


def make_host_mesh(model: int = 1):
    """A tiny mesh over whatever devices exist — for smoke tests."""
    n = len(jax.devices())
    model = min(model, n)
    return jax.make_mesh((n // model, model), ("data", "model"),
                         **axis_types_kw(2))


def make_client_mesh(*, data: int = 1, devices=None):
    """("clients", "data") mesh over the host's devices.

    The ``clients`` axis shards the leading client dim of every stacked
    pytree the grouped engine produces (params, momentum, batch plans —
    fl/sharding.py); ``data`` carries batch parallelism and defaults to 1
    because the DENSE server's synthetic batch is broadcast to every
    client anyway. Takes the leading ``(n // data) * data`` devices so a
    non-divisible device count degrades instead of failing.
    """
    devs = list(devices if devices is not None else jax.devices())
    data = max(1, min(int(data), len(devs)))
    clients = len(devs) // data
    grid = np.asarray(devs[:clients * data], dtype=object)
    return jax.sharding.Mesh(grid.reshape(clients, data),
                             ("clients", "data"))


def dp_axes_of(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
