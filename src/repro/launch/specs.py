"""Abstract input specs (ShapeDtypeStruct) for every assigned input shape —
the dry-run's stand-ins: weak-type-correct, shardable, zero allocation.

Shapes (assignment table):
  train_4k     seq 4096,    global_batch 256   -> train_step
  prefill_32k  seq 32768,   global_batch 32    -> prefill (logits + cache)
  decode_32k   seq 32768,   global_batch 128   -> serve_step (1 new token)
  long_500k    seq 524288,  global_batch 1     -> serve_step, sub-quadratic
                                                  archs only (DESIGN.md §5)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as T

SHAPES = {
    "train_4k": {"seq": 4096, "batch": 256, "kind": "train"},
    "prefill_32k": {"seq": 32768, "batch": 32, "kind": "prefill"},
    "decode_32k": {"seq": 32768, "batch": 128, "kind": "decode"},
    "long_500k": {"seq": 524288, "batch": 1, "kind": "decode"},
}

# archs with a sub-quadratic / bounded-state decode path (DESIGN.md §5)
LONG_OK_FAMILIES = ("ssm", "hybrid")
LONG_OK_ARCHS = ("gemma3-4b",)          # sliding-window dense


def long_context_ok(cfg: ArchConfig) -> bool:
    return cfg.family in LONG_OK_FAMILIES or cfg.name in LONG_OK_ARCHS


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, shape_name: str) -> dict:
    """Abstract inputs for (arch, shape): a kwargs dict whose structure
    matches what the corresponding step function expects."""
    sh = SHAPES[shape_name]
    B, S = sh["batch"], sh["seq"]
    kind = sh["kind"]
    out: dict = {"kind": kind, "batch": B, "seq": S}

    if kind == "train":
        out["batch_inputs"] = {
            "tokens": sds((B, S), jnp.int32),
            "labels": sds((B, S), jnp.int32),
        }
        if cfg.family == "vlm":
            out["batch_inputs"]["vision"] = sds(
                (B, cfg.n_patches, cfg.vision_dim), jnp.dtype(cfg.dtype))
    elif kind == "prefill":
        out["tokens"] = sds((B, S), jnp.int32)
        out["cache"] = jax.eval_shape(
            lambda: T.init_cache(cfg, B, S))
        if cfg.family == "vlm":
            out["vision"] = sds((B, cfg.n_patches, cfg.vision_dim),
                                jnp.dtype(cfg.dtype))
    else:  # decode: one new token against a seq-long cache
        out["tokens"] = sds((B, 1), jnp.int32)
        out["pos"] = sds((), jnp.int32)
        out["cache"] = jax.eval_shape(lambda: T.init_cache(cfg, B, S))
        if cfg.family == "vlm":
            out["vision"] = sds((B, cfg.n_patches, cfg.vision_dim),
                                jnp.dtype(cfg.dtype))
    return out


def abstract_params(cfg: ArchConfig):
    return jax.eval_shape(
        lambda: T.init_model(jax.random.PRNGKey(0), cfg))
