"""Block-pool KV cache + per-request SSM state slots (DESIGN.md §12).

``T.init_cache`` allocates one dense ``(batch, prompt+gen)`` cache per
fixed request batch — the serving engine instead draws from a shared
pool sized once at startup:

  * **KV pool** — per attention layer stack, ``(L, P, page, Kh, Dh)``:
    ``P`` fixed-size blocks of ``page`` tokens each. Position ``t`` of
    the request in scheduler slot ``r`` lives at
    ``(block_tables[r, t // page], t % page)``.
  * **block tables** — ``(max_reqs, M)`` int32, ``M = ceil(max_len /
    page)``; unassigned entries stay 0.
  * **SSM slots** — mamba2 decode state is O(1) per request, so it is
    slot-indexed rather than paged: the dense state tree with
    ``batch = max_reqs`` (PR 5's ``initial_state`` split≡full fix is
    what makes handing a prefill's final state into slot ``r`` exact).
  * **free list** — host-side LIFO (``BlockAllocator``). **Block 0 is
    reserved** as the null/garbage sink: inactive scheduler slots keep
    all-zero block-table rows, so their (masked-out) decode writes land
    in block 0 instead of corrupting live requests.

Prefill stays dense: a request runs the ordinary exact-length
``T.forward`` prefill, then ``scatter_prefill`` copies the filled dense
cache into its allocated blocks / state slot — the paged layout only
ever serves decode reads (kernels/paged_attention.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import backend as B
from repro.models import ssm as S

PAGED_FAMILIES = ("dense", "audio", "ssm", "hybrid")


def supports_paged(cfg) -> bool:
    """Families the paged decode path covers. moe (MLA latent cache),
    vlm (cross-attention stream) and sliding-window dense patterns fall
    back to the engine's sequential dense mode."""
    return (cfg.family in PAGED_FAMILIES and not cfg.sliding_window
            and not cfg.kv_lora_rank)


def page_size(policy=None, max_len: int | None = None) -> int:
    """The pool's page size — a cache *layout* choice owned by the
    execution-policy registry (``KERNEL_BLOCK_ARGS["paged_attention"]``),
    resolved once at pool allocation. ``max_len`` is the autotune shape
    bucket (the engine's per-request capacity) and the clamp bound."""
    pol = B.resolve_exec_policy(policy)
    if max_len is not None and B.autotune_enabled():
        (page,) = B.autotune_blocks("paged_attention", (int(max_len),), pol)
    else:
        (page,) = pol.blocks_for("paged_attention")
    if max_len is not None:
        page = min(int(page), int(max_len))
    return max(1, int(page))


def blocks_needed(prompt_len: int, max_new: int, page: int) -> int:
    """Pool blocks a request holds for its whole lifetime (allocated at
    admission — decode never allocates, so it can never deadlock
    mid-flight)."""
    return -(-(int(prompt_len) + int(max_new)) // int(page))


class BlockAllocator:
    """Host-side free-list allocator over pool blocks 1..n_blocks-1
    (block 0 is the reserved null sink and is never handed out)."""

    def __init__(self, n_blocks: int):
        if n_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is reserved)")
        self.n_blocks = int(n_blocks)
        self._free = list(range(self.n_blocks - 1, 0, -1))
        self._used: set[int] = set()

    @property
    def n_free(self) -> int:
        return len(self._free)

    def alloc(self, n: int):
        """``n`` block ids, or None if the pool can't cover the request
        (all-or-nothing: a partial grant could deadlock two admissions)."""
        if n > len(self._free):
            return None
        ids = [self._free.pop() for _ in range(n)]
        self._used.update(ids)
        return ids

    def release(self, ids):
        for i in ids:
            if i not in self._used:
                raise ValueError(f"double free of block {i}")
            self._used.remove(i)
            self._free.append(i)


# ------------------------------------------------------------- pool init --

def init_paged_cache(cfg, *, max_reqs: int, n_blocks: int, page: int):
    """The pool tree. Mirrors ``T.init_cache``'s per-family structure,
    with every attention cache's dense ``(B, T, ...)`` axes replaced by
    pool ``(P, page, ...)`` axes and every SSM state's batch axis sized
    to ``max_reqs`` slots. Zeros throughout — so unwritten pool rows are
    finite and the kernel's masked lanes multiply against real numbers.
    """
    if not supports_paged(cfg):
        raise ValueError(f"no paged cache layout for family "
                         f"{cfg.family!r} (sliding_window="
                         f"{cfg.sliding_window}, kv_lora_rank="
                         f"{cfg.kv_lora_rank}) — use the sequential "
                         "dense engine mode")
    dtype = jnp.dtype(cfg.dtype)
    fam = cfg.family

    def kv_pool(n):
        shape = (n, n_blocks, page, cfg.n_kv_heads, cfg.head_dim)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}

    def ssm_slots(lead):
        one = S.mamba2_state_init(cfg, max_reqs, dtype)
        return jax.tree.map(
            lambda a: jnp.zeros((*lead, *a.shape), a.dtype), one)

    if fam in ("dense", "audio"):
        return {"layers": kv_pool(cfg.n_layers)}
    if fam == "ssm":
        return {"layers": ssm_slots((cfg.n_layers,))}
    # hybrid: per-layer mamba2 slots + the shared attention block's pools
    n_super = cfg.n_layers // cfg.attn_every
    tail = cfg.n_layers % cfg.attn_every
    c = {"layers": ssm_slots((n_super, cfg.attn_every)),
         "shared": kv_pool(n_super)}
    if tail:
        c["tail"] = ssm_slots((tail,))
    return c


# -------------------------------------------------------- prefill scatter --

def _scatter_kv(pool, cache, row):
    """Dense prefill KV ``(L, 1, p, Kh, Dh)`` -> pool blocks ``row[:nb]``
    of ``(L, P, page, Kh, Dh)`` (tail of the last block left as zeros)."""
    page = pool["k"].shape[2]
    p = cache["k"].shape[2]
    nb = -(-p // page)
    pad = nb * page - p
    out = {}
    for n in ("k", "v"):
        c = cache[n][:, 0]                              # (L, p, Kh, Dh)
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = c.reshape(c.shape[0], nb, page, *c.shape[2:])
        out[n] = pool[n].at[:, row[:nb]].set(c.astype(pool[n].dtype))
    return out


def _scatter_slot(slots, cache, slot, *, lead: int = 1):
    """Batch-1 SSM state tree -> slot ``slot`` of the slot-indexed tree
    (``lead`` leading stack axes before the batch axis)."""
    def put(sl, c):
        pre = (slice(None),) * lead
        return sl.at[pre + (slot,)].set(c[pre + (0,)].astype(sl.dtype))
    return jax.tree.map(put, slots, cache)


def scatter_prefill(cfg, pools, block_tables, filled, slot, row):
    """Install one admitted request: copy its filled exact-length dense
    prefill cache (``T.init_cache(cfg, 1, p)`` after ``T.forward``) into
    the pool/slots and point block-table row ``slot`` at ``row`` (the
    allocated block ids, zero-padded to M). Traced-safe: ``slot`` and
    ``row`` may be tracers; shapes (p, M) are static per jit cache entry.
    Returns ``(pools, block_tables)``."""
    fam = cfg.family
    if fam in ("dense", "audio"):
        pools = {"layers": _scatter_kv(pools["layers"], filled["layers"],
                                       row)}
    elif fam == "ssm":
        pools = {"layers": _scatter_slot(pools["layers"], filled["layers"],
                                         slot)}
    elif fam == "hybrid":
        new = {"layers": _scatter_slot(pools["layers"], filled["layers"],
                                       slot, lead=2),
               "shared": _scatter_kv(pools["shared"], filled["shared"],
                                     row)}
        if "tail" in pools:
            new["tail"] = _scatter_slot(pools["tail"], filled["tail"], slot)
        pools = new
    else:
        raise ValueError(fam)
    block_tables = block_tables.at[slot].set(row)
    return pools, block_tables
