import os
os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: prove every (architecture x input shape x mesh)
combination lowers and compiles on the production mesh, and extract the
roofline terms from the compiled artifact.

No arrays are ever allocated: parameters, optimizer state and inputs are
ShapeDtypeStructs; ``jit(...).lower(...).compile()`` exercises SPMD
partitioning, layout assignment and the collective schedule exactly as a
real launch would.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-4b \
      --shape train_4k [--multi-pod] [--out artifacts/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import json
import re
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, available_archs, get_config
from repro.launch import shardings as SH
from repro.launch import specs as SP
from repro.launch import steps as ST
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               axis_types_kw, make_production_mesh)

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"=\s+(?:\()?([a-z0-9]+)\[([0-9,]*)\][^)]*?\s"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def collective_bytes(hlo: str) -> dict:
    """Sum per-device result bytes of every collective in partitioned HLO."""
    out = {"all-reduce": 0, "all-gather": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0, "count": 0}
    for m in _COLL_RE.finditer(hlo):
        dt, dims, op = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = int(np.prod([int(d) for d in dims.split(",") if d])) if dims else 1
        out[op] += n * _DTYPE_BYTES[dt]
        out["count"] += 1
    out["total"] = sum(out[k] for k in
                       ("all-reduce", "all-gather", "reduce-scatter",
                        "all-to-all", "collective-permute"))
    return out


def depth_pair(cfg: ArchConfig) -> tuple[int, int]:
    """Two small valid depths for the unrolled cost-extrapolation.

    XLA's cost_analysis counts a scanned (while-loop) body ONCE, so the
    full scanned compile under-reports FLOPs/bytes/collectives by ~L x.
    We therefore compile two small *unrolled* depth variants and linearly
    extrapolate every per-layer cost to the full depth; the full scanned
    compile remains the lowering/fit proof.
    """
    if cfg.family == "hybrid":
        u = cfg.attn_every              # one superblock = u mamba + shared
        return u, 2 * u
    if cfg.family == "vlm":
        u = cfg.cross_every + 1         # one superblock = self x4 + cross
        return u, 2 * u
    return 2, 4


def _shrink(cfg: ArchConfig, n_layers: int) -> ArchConfig:
    return cfg.replace(n_layers=n_layers, scan_layers=False)


def model_flops(cfg: ArchConfig, shape_name: str) -> float:
    """Analytic useful FLOPs for the whole step (all chips)."""
    sh = SP.SHAPES[shape_name]
    n = cfg.active_param_count()
    tokens = sh["batch"] * (sh["seq"] if sh["kind"] != "decode" else 1)
    mult = 6 if sh["kind"] == "train" else 2
    return float(mult) * n * tokens


def build_lowerable(cfg: ArchConfig, shape_name: str, mesh):
    """Returns (jitted_fn, abstract_args) for the (arch, shape) cell."""
    sh = SP.SHAPES[shape_name]
    spec = SP.input_specs(cfg, shape_name)
    aparams = SP.abstract_params(cfg)
    pspecs = SH.param_specs(cfg, aparams, mesh)
    bspec = SH.batch_specs(mesh, sh["batch"])

    if sh["kind"] == "train":
        astate = ST.abstract_train_state(cfg)
        sspecs = {
            "params": pspecs,
            "opt": {"m": SH.zero1_specs(pspecs, aparams, mesh),
                    "v": SH.zero1_specs(pspecs, aparams, mesh),
                    "t": P()},
            "step": P(),
        }
        bspecs = {k: P(bspec, *([None] * (v.ndim - 1)))
                  for k, v in spec["batch_inputs"].items()}
        fn = ST.make_train_step(cfg, mesh)
        jf = jax.jit(fn,
                     in_shardings=(SH.to_named(sspecs, mesh),
                                   SH.to_named(bspecs, mesh)),
                     donate_argnums=(0,))
        return jf, (astate, spec["batch_inputs"])

    cspecs = SH.cache_specs(cfg, spec["cache"], mesh, batch=sh["batch"])
    tok_spec = P(bspec, None)
    if sh["kind"] == "prefill":
        fn = ST.make_prefill_step(cfg, mesh)
        args = [aparams, spec["cache"], spec["tokens"]]
        in_sh = [SH.to_named(pspecs, mesh), SH.to_named(cspecs, mesh),
                 NamedSharding(mesh, tok_spec)]
        if cfg.family == "vlm":
            args.append(spec["vision"])
            in_sh.append(NamedSharding(mesh, P(bspec, None, None)))
        jf = jax.jit(fn, in_shardings=tuple(in_sh), donate_argnums=(1,))
        return jf, tuple(args)

    fn = ST.make_serve_step(cfg, mesh)
    args = [aparams, spec["cache"], spec["tokens"], spec["pos"]]
    in_sh = [SH.to_named(pspecs, mesh), SH.to_named(cspecs, mesh),
             NamedSharding(mesh, tok_spec), NamedSharding(mesh, P())]
    if cfg.family == "vlm":
        args.append(spec["vision"])
        in_sh.append(NamedSharding(mesh, P(bspec, None, None)))
    jf = jax.jit(fn, in_shardings=tuple(in_sh), donate_argnums=(1,))
    return jf, tuple(args)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             keep_hlo: bool = False, mesh_shape: str | None = None,
             cfg_overrides: dict | None = None) -> dict:
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": mesh_shape or ("2x16x16" if multi_pod else "16x16")}
    if cfg_overrides:
        rec["cfg_overrides"] = {k: str(v) for k, v in cfg_overrides.items()}
    if shape_name == "long_500k" and not SP.long_context_ok(cfg):
        rec["status"] = "skipped"
        rec["reason"] = ("pure full-attention arch: long_500k requires a "
                         "sub-quadratic path (DESIGN.md §5)")
        return rec

    if mesh_shape:  # §Perf: alternate logical meshes over the same chips
        dims = tuple(int(x) for x in mesh_shape.split("x"))
        names = ("pod", "data", "model")[-len(dims):]
        mesh = jax.make_mesh(dims, names, **axis_types_kw(len(dims)))
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    try:
        jf, args = build_lowerable(cfg, shape_name, mesh)
        with mesh:
            lowered = jf.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
    except Exception as e:  # a failure here is a bug in the system
        rec["status"] = "FAILED"
        rec["error"] = f"{type(e).__name__}: {e}"[:2000]
        return rec

    rec["status"] = "ok"
    rec["lower_s"] = round(t_lower, 1)
    rec["compile_s"] = round(t_compile, 1)

    ma = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": ma.argument_size_in_bytes,
        "output_bytes": ma.output_size_in_bytes,
        "temp_bytes": ma.temp_size_in_bytes,
        "alias_bytes": ma.alias_size_in_bytes,
        "peak_per_device": (ma.argument_size_in_bytes
                            + ma.output_size_in_bytes
                            + ma.temp_size_in_bytes
                            - ma.alias_size_in_bytes),
    }
    ca = compiled.cost_analysis()
    hlo = compiled.as_text()
    rec["raw_scanned"] = {
        "flops_per_device": float(ca.get("flops", 0.0)),
        "bytes_per_device": float(ca.get("bytes accessed", 0.0)),
        "collectives": collective_bytes(hlo),
    }

    # --- trip-count-correct costs via unrolled depth extrapolation -----
    def cell_costs(cfg_v):
        jf_v, args_v = build_lowerable(cfg_v, shape_name, mesh)
        with mesh:
            c_v = jf_v.lower(*args_v).compile()
        ca_v = c_v.cost_analysis()
        cb_v = collective_bytes(c_v.as_text())
        return {"flops": float(ca_v.get("flops", 0.0)),
                "bytes": float(ca_v.get("bytes accessed", 0.0)),
                "coll": float(cb_v["total"]),
                "coll_by_op": cb_v}

    try:
        l1, l2 = depth_pair(cfg)
        c1 = cell_costs(_shrink(cfg, l1))
        c2 = cell_costs(_shrink(cfg, l2))
        L = cfg.n_layers

        def extr(k):
            slope = (c2[k] - c1[k]) / (l2 - l1)
            return max(c1[k] + (L - l1) * slope, c1[k])

        flops_dev = extr("flops")
        bytes_dev = extr("bytes")
        coll_total = extr("coll")
        coll = {op: max(c1["coll_by_op"][op]
                        + (L - l1) * (c2["coll_by_op"][op]
                                      - c1["coll_by_op"][op]) / (l2 - l1),
                        0.0)
                for op in ("all-reduce", "all-gather", "reduce-scatter",
                           "all-to-all", "collective-permute")}
        coll["total"] = coll_total
        rec["cost_extrapolation"] = {"depths": [l1, l2], "full_depth": L,
                                     "small": c1, "big": c2}
    except Exception as e:   # fall back to raw scanned numbers
        rec["cost_extrapolation"] = {"error": f"{type(e).__name__}: {e}"[:500]}
        flops_dev = rec["raw_scanned"]["flops_per_device"]
        bytes_dev = rec["raw_scanned"]["bytes_per_device"]
        coll = rec["raw_scanned"]["collectives"]

    rec["hlo_flops_per_device"] = flops_dev
    rec["hlo_bytes_per_device"] = bytes_dev
    rec["collectives"] = coll

    mf = model_flops(cfg, shape_name)
    t_comp = flops_dev / PEAK_FLOPS_BF16
    t_mem = bytes_dev / HBM_BW
    t_coll = float(coll["total"]) / ICI_BW
    terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll}
    rec["roofline"] = terms
    rec["bottleneck"] = max(terms, key=terms.get)
    rec["model_flops_total"] = mf
    rec["useful_flops_ratio"] = (mf / (flops_dev * chips)
                                 if flops_dev else 0.0)
    if keep_hlo:
        rec["hlo_len"] = len(hlo)
    return rec


def run_dense_distill_cell(*, multi_pod: bool = False,
                           arch: str = "llama3-2-3b",
                           batch: int = 64, seq: int = 512,
                           chunked_kl: bool = False) -> dict:
    """The paper-representative production cell: DENSE stage-2 ensemble
    distillation. The homogeneous client stack's leading (ensemble) dim is
    sharded over the pod axis on the two-pod mesh — the logit average
    D(x̂) lowers to one cross-pod all-reduce (DESIGN.md §6)."""
    from repro.core import dense_llm as DL
    from repro.launch import shardings as SH

    cfg = get_config(arch).replace(scan_layers=True)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    n_clients = mesh.shape["pod"] if multi_pod else 2
    rec = {"arch": f"dense-distill-{arch}", "shape": f"b{batch}_s{seq}",
           "mesh": "2x16x16" if multi_pod else "16x16",
           "n_clients": n_clients, "chunked_kl": chunked_kl}
    try:
        state, stacked, embeds = DL.abstract_pod_inputs(
            cfg, n_clients=n_clients, batch=batch, seq=seq)
        aparams = SP.abstract_params(cfg)
        pspecs = SH.param_specs(cfg, aparams, mesh)
        # client stack: ensemble dim over 'pod' (multi-pod) else replicated
        # — the shared stacked-client-axis vocabulary (fl/sharding.py)
        cspecs = DL.pod_stack_specs(pspecs, mesh)
        sspecs = {"params": pspecs,
                  "opt": {"m": SH.zero1_specs(pspecs, aparams, mesh),
                          "v": SH.zero1_specs(pspecs, aparams, mesh),
                          "t": P()},
                  "step": P()}
        espec = P("data", None, None)
        step = ST.make_distill_step(cfg, mesh, n_clients=n_clients,
                                    chunked_kl=chunked_kl)
        jf = jax.jit(step,
                     in_shardings=(SH.to_named(sspecs, mesh),
                                   SH.to_named(cspecs, mesh),
                                   NamedSharding(mesh, espec)),
                     donate_argnums=(0,))
        t0 = time.time()
        with mesh:
            compiled = jf.lower(state, stacked, embeds).compile()
        rec["status"] = "ok"
        rec["compile_s"] = round(time.time() - t0, 1)
        ma = compiled.memory_analysis()
        rec["memory"] = {"argument_bytes": ma.argument_size_in_bytes,
                         "temp_bytes": ma.temp_size_in_bytes}
        ca = compiled.cost_analysis()
        coll = collective_bytes(compiled.as_text())
        flops_dev = float(ca.get("flops", 0.0))
        bytes_dev = float(ca.get("bytes accessed", 0.0))
        # scanned stack: scale per-layer costs (single scan over L layers
        # dominates; embedding/logits once) — coarse L-scaling documented
        rec["raw_scanned"] = {"flops_per_device": flops_dev,
                              "bytes_per_device": bytes_dev,
                              "collectives": coll}
        terms = {"compute_s": flops_dev / PEAK_FLOPS_BF16,
                 "memory_s": bytes_dev / HBM_BW,
                 "collective_s": coll["total"] / ICI_BW}
        rec["roofline_raw"] = terms
        rec["bottleneck"] = max(terms, key=terms.get)
        rec["collectives"] = coll
    except Exception as e:
        rec["status"] = "FAILED"
        rec["error"] = f"{type(e).__name__}: {e}"[:2000]
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(SP.SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--dense-distill", action="store_true",
                    help="run the paper-representative DENSE stage-2 cell")
    ap.add_argument("--mesh", default=None,
                    help="alternate logical mesh over the same chips, "
                         "e.g. 64x4 (axes data x model)")
    ap.add_argument("--baseline-attn", action="store_true",
                    help="disable blockwise attention (pre-§Perf baseline)")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    if args.dense_distill:
        os.makedirs(args.out, exist_ok=True)
        chunked = os.environ.get("DENSE_CHUNKED_KL", "") == "1"
        rec = run_dense_distill_cell(multi_pod=args.multi_pod,
                                     chunked_kl=chunked)
        tag = (f"dense-distill_{rec['shape']}"
               f"{'_chunked' if chunked else ''}_"
               f"{'2x16x16' if args.multi_pod else '16x16'}")
        with open(os.path.join(args.out, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
        print(json.dumps({k: rec.get(k) for k in
                          ("status", "compile_s", "bottleneck", "error")}))
        return

    archs = [args.arch] if args.arch else available_archs()
    shapes = [args.shape] if args.shape else list(SP.SHAPES)
    os.makedirs(args.out, exist_ok=True)

    overrides = {"use_blockwise_attn": False} if args.baseline_attn else None
    for arch in archs:
        for shape in shapes:
            mesh_tag = args.mesh or ("2x16x16" if args.multi_pod else "16x16")
            tag = f"{arch}_{shape}_{mesh_tag}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                print(f"skip {tag} (exists)")
                continue
            print(f"=== {tag} ===", flush=True)
            rec = run_cell(arch, shape, multi_pod=args.multi_pod,
                           mesh_shape=args.mesh, cfg_overrides=overrides)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            summary = {k: rec.get(k) for k in
                       ("status", "compile_s", "bottleneck", "reason",
                        "error")}
            print(json.dumps(summary), flush=True)


if __name__ == "__main__":
    main()
