"""Jittable step functions (train / distill / prefill / decode) shared by
the real drivers (train.py, serve.py) and the multi-pod dry-run
(dryrun.py)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import optim
from repro.configs.base import ArchConfig
from repro.launch.mesh import dp_axes_of
from repro.models import transformer as T


def make_train_state(key, cfg: ArchConfig, *, lr: float = 3e-4):
    params = T.init_model(key, cfg)
    opt = optim.adam(lr)
    return {"params": params, "opt": opt.init(params),
            "step": jnp.zeros((), jnp.int32)}


def abstract_train_state(cfg: ArchConfig, *, lr: float = 3e-4):
    return jax.eval_shape(
        functools.partial(make_train_state, cfg=cfg, lr=lr),
        jax.random.PRNGKey(0))


def make_train_step(cfg: ArchConfig, mesh=None, *, lr: float = 3e-4,
                    clip: float = 1.0):
    opt = optim.adam(lr)
    dp = dp_axes_of(mesh) if mesh is not None else ()

    def train_step(state, batch):
        def loss_fn(p):
            return T.loss_fn(p, cfg, batch, mesh=mesh, dp_axes=dp)

        (loss, parts), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state["params"])
        grads, gnorm = optim.clip_by_global_norm(grads, clip)
        new_p, new_opt = opt.update(grads, state["opt"], state["params"])
        new_state = {"params": new_p, "opt": new_opt,
                     "step": state["step"] + 1}
        metrics = {"loss": loss, "ce": parts["ce"],
                   "moe_aux": parts["moe_aux"], "grad_norm": gnorm}
        return new_state, metrics

    return train_step


def make_distill_step(cfg: ArchConfig, mesh, *, n_clients: int, **kw):
    """The LLM student step: DENSE stage-2 ensemble distillation against a
    pod-sharded homogeneous client stack (core.dense_llm's production
    cell, re-exported here so launch drivers and the dry-run route every
    jittable step — train / distill / prefill / decode — through one
    module). Keywords (s_lr, chunked_kl, kl_chunk, distill_kl_mode,
    kernel_vjp_mode, policy) are forwarded verbatim —
    core.dense_llm.make_pod_distill_step owns the defaults, and
    unpinned modes resolve through the backend execution-policy
    registry (configs.backend.resolve_exec_policy, DESIGN.md §11).
    distill_kl_mode="fused" runs the KL loss AND its backward through the
    Pallas custom-VJP kernel pair; kernel_vjp_mode="fused" does the same
    for the trunk's attention/SSM layers (DESIGN.md §9)."""
    from repro.core import dense_llm as DL
    return DL.make_pod_distill_step(cfg, mesh, n_clients=n_clients, **kw)


def make_prefill_step(cfg: ArchConfig, mesh=None):
    dp = dp_axes_of(mesh) if mesh is not None else ()

    def prefill_step(params, cache, tokens, vision=None):
        S = tokens.shape[1]
        positions = jnp.arange(S, dtype=jnp.int32)
        logits, new_cache, _ = T.forward(
            params, cfg, tokens=tokens, positions=positions, cache=cache,
            cache_pos=jnp.int32(0), vision=vision, mesh=mesh, dp_axes=dp,
            remat=False)
        return logits[:, -1:], new_cache

    return prefill_step


def make_serve_step(cfg: ArchConfig, mesh=None):
    """One decode step: a single new token against a pre-filled cache."""
    dp = dp_axes_of(mesh) if mesh is not None else ()

    def serve_step(params, cache, tokens, pos, vision=None):
        positions = pos[None].astype(jnp.int32)
        logits, new_cache, _ = T.forward(
            params, cfg, tokens=tokens, positions=positions, cache=cache,
            cache_pos=pos, vision=vision, mesh=mesh, dp_axes=dp,
            decode=True, remat=False)
        return logits, new_cache

    return serve_step
