"""End-to-end LM training driver (example application of the substrate).

Trains a reduced-config model on the procedural Markov LM stream on
whatever devices exist (CPU smoke / real TPU slice via the production
mesh). For the ~100M-scale end-to-end run see examples/train_lm_100m.py.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m \
      --steps 50 --batch 8 --seq 256 [--smoke] [--model-parallel 1]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs.base import get_config, get_smoke_config
from repro.data import lm_batches, make_lm_data
from repro.launch.mesh import make_host_mesh, dp_axes_of
from repro.launch import shardings as SH
from repro.launch import steps as ST


def train(arch: str, *, steps: int, batch: int, seq: int, smoke: bool,
          lr: float = 3e-4, model_parallel: int = 1, seed: int = 0,
          ckpt: str | None = None, log_every: int = 10):
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    if cfg.family == "vlm":
        vision = np.zeros((batch, cfg.n_patches, cfg.vision_dim), np.float32)
    else:
        vision = None

    mesh = make_host_mesh(model_parallel)
    key = jax.random.PRNGKey(seed)
    state = ST.make_train_state(key, cfg, lr=lr)
    step_fn = jax.jit(ST.make_train_step(cfg, mesh, lr=lr),
                      donate_argnums=(0,))

    toks = make_lm_data(seed, vocab=cfg.vocab_size,
                        n_tokens=max(200_000, batch * (seq + 1) * 4))
    t0 = time.time()
    losses = []
    with mesh:
        for i, (x, y) in enumerate(lm_batches(toks, batch, seq, seed=seed,
                                              steps=steps)):
            b = {"tokens": jnp.asarray(x), "labels": jnp.asarray(y)}
            if vision is not None:
                b["vision"] = jnp.asarray(vision)
            state, m = step_fn(state, b)
            losses.append(float(m["loss"]))
            if (i + 1) % log_every == 0:
                dt = time.time() - t0
                print(f"step {i+1:5d} loss {losses[-1]:.4f} "
                      f"ce {float(m['ce']):.4f} "
                      f"({dt/ (i+1):.2f}s/step)", flush=True)
    if ckpt:
        save_checkpoint(ckpt, state["params"],
                        meta={"arch": arch, "steps": steps,
                              "final_loss": losses[-1]})
    return state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    a = ap.parse_args()
    _, losses = train(a.arch, steps=a.steps, batch=a.batch, seq=a.seq,
                      smoke=a.smoke, lr=a.lr,
                      model_parallel=a.model_parallel, ckpt=a.ckpt)
    print(f"first loss {losses[0]:.4f} -> last {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
