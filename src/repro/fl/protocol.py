"""One-shot FL protocol orchestration + communication accounting +
server-side upload admission control.

The whole point of one-shot FL is the communication profile: exactly one
unidirectional client->server model upload. ``CommLedger`` records every
transfer so tests can assert the one-shot property (m uploads, zero
broadcasts) and benchmarks can compare against multi-round FedAvg
(2 * m * rounds transfers). Under fault injection (fl/faults.py) every
client still gets exactly one up event per round, but the event ``kind``
distinguishes ``delivered`` (counted in ``uplink_bytes``) from
``dropped``/``delayed`` (bytes never landed) and ``rejected``
(quarantined at admission — delivered bytes, excluded from aggregation).

Admission control (``admit_uploads``) is the defense half of the fault
layer (DESIGN.md §10): every arrived upload passes a finite check, a
spec/shape validation against the client's declared architecture, an
optional parameter-norm outlier screen (``scfg.norm_screen``) and an
optional leave-one-out cohort-mean cosine screen (``scfg.cos_screen`` —
catches the norm-preserving sign flips the norm screen passes by
design) before it may join the ensemble.
``scfg.upload_policy`` decides what a failed screen means:

  * ``"quarantine"`` (default) — the client is excluded via survivor
    masks (``clients.survivor_mask`` / ``clients.group_masks``) threaded
    through ``stack_grouped`` consumers, and its stacked param slot is
    ZERO-FILLED so NaN/Inf can't poison gradients through the masked
    teacher (0 cotangent x NaN param = NaN; 0 x 0 = 0).
  * ``"strict"`` — any failed screen raises ``UploadError``.

If fewer than ``ceil(scfg.quorum * m)`` uploads survive, the round
aborts loudly with ``QuorumError`` regardless of policy — a one-shot
round with too few teachers is unsalvageable and silent degradation is
worse than failure.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import jax
import numpy as np

from repro.core.ensemble import Client
from repro.data.partition import dirichlet_partition
from repro.fl.client import local_update
from repro.models.cnn import CNNSpec, cnn_init

EVENT_KINDS = ("delivered", "dropped", "delayed", "rejected")


def param_bytes(tree) -> int:
    return sum(np.asarray(x).nbytes for x in jax.tree.leaves(tree))


class UploadError(ValueError):
    """An upload failed admission under ``upload_policy="strict"``."""


class QuorumError(RuntimeError):
    """Fewer than ``quorum * m`` uploads survived admission."""


@dataclass
class CommLedger:
    events: list = field(default_factory=list)

    def record(self, direction: str, who: str, nbytes: int, what: str,
               kind: str = "delivered"):
        if direction not in ("up", "down"):
            raise ValueError(f"direction must be 'up' or 'down', "
                             f"got {direction!r}")
        if kind not in EVENT_KINDS:
            raise ValueError(f"kind must be one of {EVENT_KINDS}, "
                             f"got {kind!r}")
        self.events.append({"dir": direction, "who": who,
                            "bytes": int(nbytes), "what": what,
                            "kind": kind})

    @property
    def uplink_bytes(self) -> int:
        """Bytes that actually landed at the server. A later-quarantined
        upload still consumed uplink — it keeps its ``delivered`` event;
        the admission layer's ``rejected`` events are zero-byte audit
        markers, so only ``delivered`` bytes are summed."""
        return sum(e["bytes"] for e in self.events
                   if e["dir"] == "up"
                   and e.get("kind", "delivered") == "delivered")

    @property
    def downlink_bytes(self) -> int:
        return sum(e["bytes"] for e in self.events if e["dir"] == "down")

    @property
    def rounds(self) -> int:
        """Number of distinct up-transfer phases (communication rounds)."""
        return len({e["what"] for e in self.events if e["dir"] == "up"})

    def kinds(self, kind: str, direction: str = "up") -> list:
        """Events of one kind (convenience for fault-accounting asserts)."""
        return [e for e in self.events if e["dir"] == direction
                and e.get("kind", "delivered") == kind]


# ------------------------------------------------------------ admission ---

def _template_shapes(spec: CNNSpec, cache={}):
    """Expected (path -> shape/dtype) for one client architecture, from a
    throwaway ``cnn_init`` (cached per spec — init is cheap at test scale
    but admission runs once per client)."""
    if spec not in cache:
        tpl = cnn_init(jax.random.PRNGKey(0), spec)
        cache[spec] = jax.tree.map(
            lambda a: (np.shape(a), np.asarray(a).dtype), tpl)
    return cache[spec]


def validate_upload(params, spec: CNNSpec) -> str | None:
    """Spec/shape + finite screen for one upload.

    Returns None when admissible, else a human-readable reason string.
    """
    tpl = _template_shapes(spec)
    p_leaves, p_def = jax.tree_util.tree_flatten(params)
    # tpl leaves are (shape, dtype) tuples — flatten with is_leaf
    t_leaves, t_def = jax.tree_util.tree_flatten(
        tpl, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
        and isinstance(x[0], tuple))
    if t_def != p_def:
        return f"treedef mismatch vs {spec.kind} template"
    for (shape, dtype), leaf in zip(t_leaves, p_leaves):
        a = np.asarray(leaf)
        if a.shape != shape:
            return (f"shape mismatch vs {spec.kind} template: "
                    f"got {a.shape}, want {shape}")
        if np.issubdtype(a.dtype, np.floating) and not np.all(
                np.isfinite(a)):
            return "non-finite parameters"
    return None


def norm_outliers(clients, candidates, threshold: float) -> dict[int, str]:
    """MAD-based parameter-norm outlier screen over same-spec cohorts.

    For each architecture cohort with >= 5 candidates, flags clients whose
    global param norm deviates from the cohort median by more than
    ``threshold`` median-absolute-deviations. Opt-in
    (``scfg.norm_screen > 0``); small cohorts are skipped — a 3-client
    median is noise, not a defense. Sign flips are norm-preserving and
    pass by design (the documented gap, DESIGN.md §10 — closed by the
    opt-in ``direction_outliers`` cosine screen below).
    """
    from repro.optim.optimizers import global_norm
    out: dict[int, str] = {}
    cohorts: dict[CNNSpec, list[int]] = {}
    for i in candidates:
        cohorts.setdefault(clients[i].spec, []).append(i)
    for spec, idx in cohorts.items():
        if len(idx) < 5:
            continue
        norms = np.array([float(global_norm(clients[i].params))
                          for i in idx])
        med = np.median(norms)
        mad = np.median(np.abs(norms - med))
        if mad == 0.0:
            continue
        for i, n in zip(idx, norms):
            dev = abs(n - med) / mad
            if dev > threshold:
                out[i] = (f"param-norm outlier: {n:.3g} is {dev:.1f} MADs "
                          f"from cohort median {med:.3g}")
    return out


def direction_outliers(clients, candidates, threshold: float) -> dict[int, str]:
    """Leave-one-out cohort-mean cosine screen — closes the norm screen's
    sign-flip gap (DESIGN.md §10): a negated upload keeps its norm
    exactly but points AWAY from every honest peer, so its cosine to the
    cohort mean is ≈ -1 while honest clients trained on same-distribution
    shards cluster directionally (cosine well above 0 post-training; raw
    random inits do NOT cluster, which is why this is opt-in —
    ``scfg.cos_screen``, None = off).

    The mean must exclude the candidate itself: with self included, a
    flipped upload's own -p_i term dominates the correlation and drags
    its cosine back toward +1/sqrt(m). So for cohort sum S = Σ p_j the
    screen tests cos(p_i, S - p_i) < threshold (cosine to the
    leave-one-out sum equals cosine to the leave-one-out mean — positive
    scaling). Two passes over the cohort keep host memory at O(P) — one
    flattened vector plus the running sum — never O(m·P), which is what
    lets the screen run at the m=1000 federation target.

    Cohorts with < 5 candidates are skipped, matching ``norm_outliers``:
    a tiny cohort's mean direction is noise, not a defense.
    """
    out: dict[int, str] = {}
    cohorts: dict[CNNSpec, list[int]] = {}
    for i in candidates:
        cohorts.setdefault(clients[i].spec, []).append(i)

    def flat(p):
        return np.concatenate([np.asarray(a, np.float64).ravel()
                               for a in jax.tree.leaves(p)])

    for spec, idx in cohorts.items():
        if len(idx) < 5:
            continue
        s = None
        for i in idx:                     # pass 1: streaming cohort sum
            v = flat(clients[i].params)
            s = v if s is None else s + v
        for i in idx:                     # pass 2: leave-one-out cosine
            v = flat(clients[i].params)
            loo = s - v
            nv, nl = np.linalg.norm(v), np.linalg.norm(loo)
            if nv == 0.0 or nl == 0.0:
                continue
            cos = float(np.dot(v, loo) / (nv * nl))
            if cos < threshold:
                out[i] = (f"direction outlier: cosine {cos:.3f} to "
                          f"leave-one-out cohort mean < "
                          f"threshold {threshold}")
    return out


def _zero_like(params):
    return jax.tree.map(lambda a: np.zeros_like(np.asarray(a)), params)


def admit_uploads(clients, *, arrived=None, scfg=None,
                  upload_policy: str | None = None,
                  quorum: float | None = None,
                  norm_screen: float | None = None,
                  cos_screen: float | None = None,
                  ledger: CommLedger | None = None,
                  upload_tag: str = "round0-model-upload"):
    """Server-side admission control: screen every arrived upload, build
    the survivor-masked federation.

    Returns a ``ClientList`` with three extra attributes:

      * ``survivor_mask`` — (m,) STATIC numpy bool, True = admitted;
      * ``group_masks``   — per-group numpy bool arrays aligned with the
        grouped representation's client axis (None for fully-surviving
        groups — the common case keeps the unmasked compiled paths);
      * ``quarantined``   — {client_index: reason}.

    Quarantined/missing clients keep their ``Client`` entry (callers may
    inspect them) but their stacked param slot is zero-filled and their
    mask bit cleared, so every masked consumer — grouped_ensemble_logits,
    the shard_map psum teacher, fedavg_stacked — produces bit-identical
    results to a federation built without them (adding exact zeros is
    exact in any reduction order).

    The masks are host-side constants baked into jit at trace time: no
    dynamic-shape tracing, and fully-quarantined groups are statically
    skipped.
    """
    from repro.fl.federation import ClientList

    policy = upload_policy if upload_policy is not None else \
        getattr(scfg, "upload_policy", "quarantine")
    if policy not in ("strict", "quarantine"):
        raise ValueError(f"upload_policy must be 'strict' or 'quarantine', "
                         f"got {policy!r}")
    q = quorum if quorum is not None else getattr(scfg, "quorum", 0.5)
    screen = norm_screen if norm_screen is not None else \
        getattr(scfg, "norm_screen", 0.0)
    cscreen = cos_screen if cos_screen is not None else \
        getattr(scfg, "cos_screen", None)

    m = len(clients)
    arrived = np.ones(m, bool) if arrived is None else np.asarray(
        arrived, bool)
    quarantined: dict[int, str] = {}
    for i in range(m):
        if not arrived[i]:
            quarantined[i] = "upload never arrived"
            continue
        reason = validate_upload(clients[i].params, clients[i].spec)
        if reason is not None:
            quarantined[i] = reason
    if screen and screen > 0:
        ok = [i for i in range(m) if i not in quarantined]
        quarantined.update(norm_outliers(clients, ok, float(screen)))
    if cscreen is not None:
        ok = [i for i in range(m) if i not in quarantined]
        quarantined.update(direction_outliers(clients, ok, float(cscreen)))

    rejected = {i: r for i, r in quarantined.items() if arrived[i]}
    if policy == "strict" and rejected:
        i, reason = next(iter(sorted(rejected.items())))
        raise UploadError(
            f"client{i} upload failed admission under strict policy: "
            f"{reason}")
    if ledger is not None:
        # zero-byte audit markers under the SAME tag: the rejected
        # upload's bytes are already on its "delivered" event, and a new
        # tag would inflate ledger.rounds
        for i, reason in sorted(rejected.items()):
            ledger.record("up", f"client{i}", 0, upload_tag,
                          kind="rejected")

    survivor = np.array([i not in quarantined for i in range(m)], bool)
    need = math.ceil(q * m)
    if int(survivor.sum()) < need:
        raise QuorumError(
            f"quorum failure: {int(survivor.sum())}/{m} uploads survived "
            f"admission, need >= {need} (quorum={q}); quarantined: "
            f"{ {i: r for i, r in sorted(quarantined.items())} }")

    if survivor.all():
        out = ClientList(list(clients), *stack_or_reuse(clients))
        out.survivor_mask = survivor
        out.group_masks = [None] * len(out.grouped[0])
        out.quarantined = {}
        return out

    # zero-fill quarantined slots, then restack + build per-group masks
    from repro.fl.faults import rebuild_clients
    new_params = [(_zero_like(c.params) if i in quarantined else c.params)
                  for i, c in enumerate(clients)]
    out = rebuild_clients(clients, new_params)
    from repro.core.ensemble import group_clients
    group_masks = []
    for spec, idx in group_clients(out):
        gm = survivor[list(idx)]
        group_masks.append(None if gm.all() else gm)
    out.survivor_mask = survivor
    out.group_masks = group_masks
    out.quarantined = quarantined
    return out


def stack_or_reuse(clients):
    """(gspecs, gparams) — the prebuilt grouped representation when the
    federation carries one, else a fresh ``stack_grouped``."""
    from repro.core.ensemble import stack_grouped
    gspecs, gparams = stack_grouped(clients)
    return gspecs, gparams


# ----------------------------------------------------------- federation ---

def build_federation(key, scfg, data, *, ledger: CommLedger | None = None,
                     seed: int = 0, round: int = 0, pending=None,
                     return_faults: bool = False):
    """Partition data (Dirichlet, §3.1.2), train every client locally,
    and 'upload' the models: the one communication round of DENSE.

    Returns (clients, shards) where shards[i] = (x_i, y_i).

    The resolved execution policy (configs.backend.resolve_exec_policy;
    ``scfg.client_loop_mode`` when set) selects the LocalUpdate driver
    (mirroring the server loop's loop mode):

      * ``"grouped"`` (the registry default on every backend) — the
        fl/federation.py engine: clients
        are grouped by architecture and each group trains as ONE
        vmapped+scanned program; the returned ``ClientList`` carries the
        stacked params straight into ``core.ensemble.stack_grouped``.
      * ``"python"`` — the per-client reference loop (one jitted step per
        minibatch), kept as ground truth for the equivalence tests.

    Both consume identical per-client init keys and minibatch seeds and
    agree to float tolerance (tests/test_federation.py).

    With a fault plan configured (``scfg.fault_plan`` /
    ``scfg.dropout_frac``), training runs ledger-silent and the upload
    boundary is owned by ``fl.faults.apply_upload_faults`` + admission:
    the ledger then records what actually happened per client (delivered /
    dropped / delayed / rejected) instead of assuming every upload lands.
    The no-fault path records exactly as before. ``return_faults=True``
    additionally returns the (arrived, delayed) fault outcome — the
    multi-round driver needs ``delayed`` to carry stale uploads forward.
    """
    from repro.fl.faults import apply_upload_faults, build_fault_plan

    plan = build_fault_plan(scfg, round=round) if scfg is not None else {}
    faulty = bool(plan) or bool(pending)
    train_ledger = None if faulty else ledger

    from repro.configs.backend import resolve_exec_policy
    mode = resolve_exec_policy(scfg).client_loop
    if mode == "grouped":
        from repro.fl.federation import build_grouped_federation
        clients, shards = build_grouped_federation(
            key, scfg, data, ledger=train_ledger, seed=seed)
    else:
        clients, shards = _build_python_federation(
            key, scfg, data, ledger=train_ledger, seed=seed)

    if not faulty:
        if return_faults:
            return clients, shards, (np.ones(len(clients), bool), {})
        return clients, shards
    fault_key = jax.random.PRNGKey(
        int(getattr(scfg, "fault_seed", 0)) * 7919 + round)
    tag = f"round{round}-model-upload" if round else "round0-model-upload"
    clients, arrived, delayed = apply_upload_faults(
        clients, plan, key=fault_key, ledger=ledger, upload_tag=tag,
        pending=pending)
    clients = admit_uploads(clients, arrived=arrived, scfg=scfg,
                            ledger=ledger, upload_tag=tag)
    if return_faults:
        return clients, shards, (arrived, delayed)
    return clients, shards


def _build_python_federation(key, scfg, data, *, ledger, seed):
    """The per-client reference LocalUpdate loop (ground truth)."""
    x, y = data["train"]
    parts = dirichlet_partition(y, scfg.n_clients, scfg.alpha, seed=seed)
    clients, shards = [], []
    keys = jax.random.split(key, scfg.n_clients)
    for i, idx in enumerate(parts):
        spec = CNNSpec(kind=scfg.client_kinds[i % len(scfg.client_kinds)],
                       num_classes=scfg.num_classes, in_ch=scfg.in_ch,
                       width=scfg.width, image_size=scfg.image_size)
        params = cnn_init(keys[i], spec)
        params, info = local_update(
            params, spec, x[idx], y[idx], epochs=scfg.local_epochs,
            lr=scfg.local_lr, momentum=scfg.local_momentum,
            batch_size=scfg.batch_size, use_ldam=scfg.use_ldam,
            num_classes=scfg.num_classes, seed=seed + i)
        if ledger is not None:
            ledger.record("up", f"client{i}", param_bytes(params),
                          "round0-model-upload")
        clients.append(Client(spec=spec, params=params, n_data=len(idx),
                              class_counts=info["class_counts"]))
        shards.append((x[idx], y[idx]))
    return clients, shards
