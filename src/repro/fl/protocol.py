"""One-shot FL protocol orchestration + communication accounting.

The whole point of one-shot FL is the communication profile: exactly one
unidirectional client->server model upload. ``CommLedger`` records every
transfer so tests can assert the one-shot property (m uploads, zero
broadcasts) and benchmarks can compare against multi-round FedAvg
(2 * m * rounds transfers).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import numpy as np

from repro.core.ensemble import Client
from repro.data.partition import dirichlet_partition
from repro.fl.client import local_update
from repro.models.cnn import CNNSpec, cnn_init


def param_bytes(tree) -> int:
    return sum(np.asarray(x).nbytes for x in jax.tree.leaves(tree))


@dataclass
class CommLedger:
    events: list = field(default_factory=list)

    def record(self, direction: str, who: str, nbytes: int, what: str):
        assert direction in ("up", "down")
        self.events.append({"dir": direction, "who": who,
                            "bytes": int(nbytes), "what": what})

    @property
    def uplink_bytes(self) -> int:
        return sum(e["bytes"] for e in self.events if e["dir"] == "up")

    @property
    def downlink_bytes(self) -> int:
        return sum(e["bytes"] for e in self.events if e["dir"] == "down")

    @property
    def rounds(self) -> int:
        """Number of distinct up-transfer phases (communication rounds)."""
        return len({e["what"] for e in self.events if e["dir"] == "up"})


def build_federation(key, scfg, data, *, ledger: CommLedger | None = None,
                     seed: int = 0):
    """Partition data (Dirichlet, §3.1.2), train every client locally,
    and 'upload' the models: the one communication round of DENSE.

    Returns (clients, shards) where shards[i] = (x_i, y_i).

    ``scfg.client_loop_mode`` selects the LocalUpdate driver (mirroring
    ``scfg.loop_mode`` for the server loop):

      * ``"grouped"`` (default) — the fl/federation.py engine: clients
        are grouped by architecture and each group trains as ONE
        vmapped+scanned program; the returned ``ClientList`` carries the
        stacked params straight into ``core.ensemble.stack_grouped``.
      * ``"python"`` — the per-client reference loop (one jitted step per
        minibatch), kept as ground truth for the equivalence tests.

    Both consume identical per-client init keys and minibatch seeds and
    agree to float tolerance (tests/test_federation.py).
    """
    mode = getattr(scfg, "client_loop_mode", "grouped")
    if mode == "grouped":
        from repro.fl.federation import build_grouped_federation
        return build_grouped_federation(key, scfg, data, ledger=ledger,
                                        seed=seed)
    if mode != "python":
        raise ValueError(f"unknown client_loop_mode {mode!r} "
                         "(expected 'python' or 'grouped')")
    x, y = data["train"]
    parts = dirichlet_partition(y, scfg.n_clients, scfg.alpha, seed=seed)
    clients, shards = [], []
    keys = jax.random.split(key, scfg.n_clients)
    for i, idx in enumerate(parts):
        spec = CNNSpec(kind=scfg.client_kinds[i % len(scfg.client_kinds)],
                       num_classes=scfg.num_classes, in_ch=scfg.in_ch,
                       width=scfg.width, image_size=scfg.image_size)
        params = cnn_init(keys[i], spec)
        params, info = local_update(
            params, spec, x[idx], y[idx], epochs=scfg.local_epochs,
            lr=scfg.local_lr, momentum=scfg.local_momentum,
            batch_size=scfg.batch_size, use_ldam=scfg.use_ldam,
            num_classes=scfg.num_classes, seed=seed + i)
        if ledger is not None:
            ledger.record("up", f"client{i}", param_bytes(params),
                          "round0-model-upload")
        clients.append(Client(spec=spec, params=params, n_data=len(idx),
                              class_counts=info["class_counts"]))
        shards.append((x[idx], y[idx]))
    return clients, shards
