"""Seeded upload-fault injection: the chaos half of the fault-tolerant
one-shot round (DESIGN.md §10).

DENSE's single communication round cannot be retried, so the robustness
of that one round is the whole ballgame: a client whose upload never
arrives, arrives corrupted (NaN/Inf), or arrives adversarially perturbed
(scaled noise, sign flip) must not take the run down with it. This module
owns the *injection* side — a deterministic, per-client fault plan applied
at the upload boundary of both LocalUpdate engines — and
``fl.protocol.admit_uploads`` owns the *defense* side (finite/shape/norm
screens, quarantine masks, quorum).

Fault kinds (``FAULT_KINDS``):

  * ``drop``     — the upload never arrives (straggler/crash). Recorded as
                   a ``kind="dropped"`` CommLedger event; excluded from
                   ``uplink_bytes`` (the bytes never landed).
  * ``delay``    — the upload arrives one round late (multi-round only;
                   in the one-shot round there is no next round, so it
                   degenerates to ``drop``). The stale round-r params are
                   presented as the client's round-(r+1) upload.
  * ``nan``/``inf`` — bitrot/overflow corruption: a seeded fraction of
                   every leaf is overwritten with NaN/Inf. Caught by the
                   admission finite screen.
  * ``noise``    — Byzantine scaled-noise perturbation: params +=
                   scale * sigma_leaf * N(0, 1) per leaf. Caught by the
                   parameter-norm outlier screen (when enabled).
  * ``signflip`` — Byzantine sign flip (params -> -params). Norm-preserving
                   by construction: it deliberately PASSES the norm screen
                   (the documented detection gap — DESIGN.md §10). Caught
                   by the opt-in leave-one-out cohort-mean cosine screen
                   (``scfg.cos_screen``, fl.protocol.direction_outliers):
                   a flipped upload points away from its trained cohort,
                   cosine ≈ -1 to the leave-one-out mean.

Determinism: the plan is a pure function of ``(scfg.fault_plan,
scfg.dropout_frac, scfg.fault_seed, round)`` and every corruption derives
its noise from ``jax.random.fold_in(key, client_index)``, so a chaos run
replays bit-identically.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

FAULT_KINDS = ("drop", "delay", "nan", "inf", "noise", "signflip")

# fraction of each leaf's elements overwritten by nan/inf corruption
# (at least one element per leaf, so a single-scalar leaf is still hit)
_CORRUPT_FRAC = 0.01


@dataclass(frozen=True)
class Fault:
    """One planned upload fault: ``client``'s round-``round`` upload."""
    client: int
    kind: str
    scale: float = 10.0            # noise multiplier (kind="noise" only)
    round: int = 0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(expected one of {FAULT_KINDS})")


def normalize_plan(plan) -> tuple[Fault, ...]:
    """Accept ``Fault`` instances or (client, kind[, scale[, round]])
    tuples — the form a frozen scfg dataclass can hold."""
    out = []
    for f in plan or ():
        out.append(f if isinstance(f, Fault) else Fault(*f))
    return tuple(out)


def build_fault_plan(scfg, *, round: int = 0,
                     n_clients: int | None = None) -> dict[int, Fault]:
    """The per-client fault plan for one round: explicit ``scfg.fault_plan``
    entries plus ``scfg.dropout_frac`` seeded drop faults.

    dropout_frac picks ``round(frac * m)`` clients per round with
    ``np.random.default_rng(fault_seed + round)`` — deterministic, and
    disjoint from explicitly-planned clients.
    """
    m = n_clients if n_clients is not None else scfg.n_clients
    plan = {f.client: f
            for f in normalize_plan(getattr(scfg, "fault_plan", ()))
            if f.round == round}
    for i in plan:
        if not 0 <= i < m:
            raise ValueError(f"fault_plan client {i} out of range for "
                             f"m={m}")
    frac = float(getattr(scfg, "dropout_frac", 0.0))
    if frac:
        if not 0.0 <= frac < 1.0:
            raise ValueError(f"dropout_frac must be in [0, 1), got {frac}")
        rng = np.random.default_rng(
            int(getattr(scfg, "fault_seed", 0)) + round)
        free = [i for i in range(m) if i not in plan]
        k = min(len(free), int(np.round(frac * m)))
        for i in rng.choice(len(free), size=k, replace=False):
            plan[free[int(i)]] = Fault(client=free[int(i)], kind="drop",
                                       round=round)
    return plan


def corrupt_params(params, kind: str, *, key, scale: float = 10.0):
    """Pure, seeded corruption of one upload's params pytree."""
    if kind == "signflip":
        return jax.tree.map(lambda a: -a, params)
    leaves, treedef = jax.tree_util.tree_flatten(params)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, a in zip(keys, leaves):
        a = jnp.asarray(a)
        if kind == "noise":
            sigma = jnp.std(a.astype(jnp.float32)) + 1e-8
            out.append((a.astype(jnp.float32) + scale * sigma
                        * jax.random.normal(k, a.shape)).astype(a.dtype))
        elif kind in ("nan", "inf"):
            bad = jnp.float32(jnp.nan if kind == "nan" else jnp.inf)
            u = jax.random.uniform(k, a.shape)
            hit = u < jnp.maximum(_CORRUPT_FRAC,
                                  1.0 / max(a.size, 1))      # >=1 expected
            out.append(jnp.where(hit, bad, a.astype(jnp.float32))
                       .astype(a.dtype))
        else:
            raise ValueError(f"corrupt_params cannot apply kind {kind!r}")
    return jax.tree_util.tree_unflatten(treedef, out)


def rebuild_clients(clients, new_params: Sequence):
    """Clone a federation with per-client params replaced, preserving the
    grouped no-restack representation for untouched groups."""
    from repro.core.ensemble import Client, group_clients
    from repro.fl.federation import ClientList

    rebuilt = [Client(spec=c.spec, params=new_params[i], n_data=c.n_data,
                      class_counts=c.class_counts)
               for i, c in enumerate(clients)]
    groups = group_clients(clients)
    pre = getattr(clients, "grouped", None)
    gspecs, gparams = [], []
    for gi, (spec, idx) in enumerate(groups):
        gspecs.append((spec, len(idx)))
        changed = any(new_params[i] is not clients[i].params for i in idx)
        if pre is not None and not changed:
            gparams.append(pre[1][gi])          # untouched: no restack
        elif len(idx) == 1:
            gparams.append(new_params[idx[0]])
        else:
            gparams.append(jax.tree.map(lambda *xs: jnp.stack(xs),
                                        *[new_params[i] for i in idx]))
    return ClientList(rebuilt, gspecs, gparams)


def apply_upload_faults(clients, plan: dict[int, "Fault"], *, key,
                        ledger=None, upload_tag: str = "round0-model-upload",
                        pending: dict | None = None):
    """Apply one round's fault plan at the upload boundary.

    Returns ``(clients, arrived, delayed)``:

      * ``clients`` — the federation with corrupted uploads substituted
        (drop/delay leave params in place; ``arrived`` marks them missing);
      * ``arrived`` — (m,) bool; False where the upload never landed this
        round (drop, delay);
      * ``delayed`` — {client: params} withheld by ``delay`` faults, to be
        presented as next round's upload (multi-round).

    ``pending`` (previous round's delayed uploads) are substituted as this
    round's arrivals for those clients — the stale-upload semantics of a
    straggler that is exactly one round behind.

    Ledger accounting (``CommLedger`` kinds): every client gets exactly one
    ``dir="up"`` event per round — ``delivered`` (counted in uplink_bytes),
    ``dropped`` or ``delayed`` (bytes never landed, excluded). Admission
    later adds zero-byte ``rejected`` events for quarantined arrivals.
    """
    from repro.fl.protocol import param_bytes

    m = len(clients)
    arrived = np.ones(m, bool)
    delayed: dict[int, object] = {}
    new_params = [c.params for c in clients]
    for i, fault in sorted(plan.items()):
        nbytes = param_bytes(clients[i].params)
        if fault.kind in ("drop", "delay"):
            arrived[i] = False
            if fault.kind == "delay":
                delayed[i] = clients[i].params
            if ledger is not None:
                ledger.record("up", f"client{i}", nbytes, upload_tag,
                              kind="dropped" if fault.kind == "drop"
                              else "delayed")
        else:
            new_params[i] = corrupt_params(
                clients[i].params, fault.kind,
                key=jax.random.fold_in(key, i), scale=fault.scale)
    for i, stale in (pending or {}).items():
        new_params[i] = stale                  # last round's upload lands
        arrived[i] = True
    if ledger is not None:
        for i in range(m):
            if arrived[i]:
                ledger.record("up", f"client{i}",
                              param_bytes(new_params[i]), upload_tag)
    changed = any(new_params[i] is not clients[i].params for i in range(m))
    if changed:
        clients = rebuild_clients(clients, new_params)
    return clients, arrived, delayed


__all__ = ["FAULT_KINDS", "Fault", "normalize_plan", "build_fault_plan",
           "corrupt_params", "apply_upload_faults", "rebuild_clients"]
