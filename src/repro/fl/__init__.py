from repro.fl.client import local_update, make_local_step
from repro.fl.fedavg import fedavg
from repro.fl.protocol import CommLedger, build_federation, param_bytes
from repro.fl.baselines import fed_df, fed_dafl, fed_adi, make_distill_step
from repro.fl.multiround import dense_multi_round

__all__ = ["local_update", "make_local_step", "fedavg", "CommLedger",
           "build_federation", "param_bytes", "fed_df", "fed_dafl",
           "fed_adi", "make_distill_step", "dense_multi_round"]
