from repro.fl.client import (local_update, local_update_bucketed,
                             local_update_grouped,
                             make_grouped_local_update, make_local_step)
from repro.fl.fedavg import fedavg, fedavg_stacked
from repro.fl.federation import (ClientList, build_grouped_federation,
                                 client_specs, group_specs,
                                 train_clients_grouped)
from repro.fl.protocol import (CommLedger, QuorumError, UploadError,
                               admit_uploads, build_federation,
                               direction_outliers, param_bytes,
                               validate_upload)
from repro.fl.faults import (FAULT_KINDS, Fault, apply_upload_faults,
                             build_fault_plan, corrupt_params)
from repro.fl.baselines import fed_df, fed_dafl, fed_adi, make_distill_step
from repro.fl.multiround import dense_multi_round
from repro.fl.sharding import (CLIENT_AXIS, group_shardable, put_grouped,
                               put_stacked, resolve_mesh, stack_specs)

__all__ = ["local_update", "local_update_bucketed", "local_update_grouped",
           "make_grouped_local_update", "make_local_step", "fedavg",
           "fedavg_stacked", "ClientList", "build_grouped_federation",
           "client_specs", "group_specs", "train_clients_grouped",
           "CommLedger", "QuorumError", "UploadError", "admit_uploads",
           "build_federation", "direction_outliers", "param_bytes",
           "validate_upload",
           "FAULT_KINDS", "Fault", "apply_upload_faults",
           "build_fault_plan", "corrupt_params", "fed_df",
           "fed_dafl", "fed_adi", "make_distill_step", "dense_multi_round",
           "CLIENT_AXIS", "group_shardable", "put_grouped", "put_stacked",
           "resolve_mesh", "stack_specs"]
