"""One-shot FL baselines from the paper (§3.1.3).

  FedDF    [37] — ensemble distillation on a transfer set. FedDF assumes an
                  unlabeled proxy dataset; in the paper's data-free one-shot
                  comparison no proxy exists, so it receives random-noise
                  inputs (recorded adaptation, DESIGN.md §7).
  Fed-DAFL [2]  — DAFL's GAN-based data-free KD applied to the ensemble:
                  generator trained with one-hot CE + activation norm +
                  information-entropy losses; no BN / boundary terms.
  Fed-ADI  [57] — DeepInversion: optimize input batches directly with
                  CE + BN-statistics + TV + L2 priors, then distill.

All baselines share DENSE's distillation step (Eq. 6) and the same student
budget — matching the paper's "same setting for all methods". Client
setup also matches: every method consumes the federation built by
``fl.protocol.build_federation`` (the grouped client-training engine by
default), and ``stack_grouped`` below receives the engine's stacked
params directly — no per-method restacking.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import optim
from repro.core import losses as LS
from repro.core.dense import merge_bn_stats
from repro.core.ensemble import grouped_ensemble_logits, stack_grouped
from repro.core import generator as G
from repro.models.cnn import CNNSpec, cnn_apply, cnn_init


def _student_spec(scfg) -> CNNSpec:
    return CNNSpec(kind=scfg.global_kind, num_classes=scfg.num_classes,
                   in_ch=scfg.in_ch, width=scfg.width,
                   image_size=scfg.image_size)


def make_distill_step(gspecs, student_spec: CNNSpec, scfg):
    """Shared Eq.-6 distillation step over the grouped ensemble
    (gspecs/gparams from ensemble.stack_grouped)."""
    s_opt = optim.sgd(scfg.s_lr, momentum=scfg.s_momentum)

    @jax.jit
    def step(stu_p, s_state, gparams, x):
        avg = grouped_ensemble_logits(gspecs, gparams, x)

        def loss_fn(sp):
            logits, new_sp, _ = cnn_apply(sp, student_spec, x, train=True)
            return LS.distill_loss(avg, logits), new_sp

        (loss, stats_p), grads = jax.value_and_grad(loss_fn, has_aux=True)(stu_p)
        new_p, new_state = s_opt.update(grads, s_state, stu_p)
        return merge_bn_stats(new_p, stats_p), new_state, loss

    return step, s_opt


# ------------------------------------------------------------------ FedDF --

def fed_df(key, clients, scfg, student_spec: CNNSpec | None = None):
    student_spec = student_spec or _student_spec(scfg)
    gspecs, gparams = stack_grouped(clients)
    k_s, key = jax.random.split(key)
    stu_p = cnn_init(k_s, student_spec)
    step, s_opt = make_distill_step(gspecs, student_spec, scfg)
    s_state = s_opt.init(stu_p)
    for _ in range(scfg.epochs):
        for _ in range(getattr(scfg, "s_steps", 1)):
            key, kx = jax.random.split(key)
            x = jax.random.uniform(kx, (scfg.synth_batch, scfg.image_size,
                                        scfg.image_size, scfg.in_ch),
                                   jnp.float32, -1.0, 1.0)
            stu_p, s_state, _ = step(stu_p, s_state, gparams, x)
    return stu_p, student_spec


# --------------------------------------------------------------- Fed-DAFL --

def fed_dafl(key, clients, scfg, student_spec: CNNSpec | None = None, *,
             alpha: float = 0.1, beta: float = 5.0):
    student_spec = student_spec or _student_spec(scfg)
    gspecs, gparams = stack_grouped(clients)
    k_g, k_s, key = jax.random.split(key, 3)
    gen_p = G.img_generator_init(k_g, nz=scfg.nz, img_size=scfg.image_size,
                                 out_ch=scfg.in_ch)
    stu_p = cnn_init(k_s, student_spec)
    g_opt = optim.adam(scfg.g_lr)
    g_state = g_opt.init(gen_p)
    d_step, s_opt = make_distill_step(gspecs, student_spec, scfg)
    s_state = s_opt.init(stu_p)

    @jax.jit
    def gen_step(gp, gs, gparams, z):
        def loss_fn(gp):
            x = G.img_generator(gp, z, img_size=scfg.image_size)
            avg = grouped_ensemble_logits(gspecs, gparams, x)
            pseudo = jnp.argmax(avg, -1)
            l_oh = LS.ce_loss(avg, pseudo)                  # one-hot loss
            l_a = -jnp.mean(jnp.abs(avg))                   # activation loss
            mean_p = jnp.mean(jax.nn.softmax(avg, -1), 0)
            l_ie = jnp.sum(mean_p * jnp.log(mean_p + 1e-8))  # -entropy
            return l_oh + alpha * l_a + beta * l_ie

        loss, grads = jax.value_and_grad(loss_fn)(gp)
        new_p, new_s = g_opt.update(grads, gs, gp)
        return new_p, new_s, loss

    for _ in range(scfg.epochs):
        key, kz = jax.random.split(key)
        z = jax.random.normal(kz, (scfg.synth_batch, scfg.nz))
        for _ in range(scfg.t_g):
            gen_p, g_state, _ = gen_step(gen_p, g_state, gparams, z)
        for _ in range(getattr(scfg, "s_steps", 1)):
            x = jax.lax.stop_gradient(
                G.img_generator(gen_p, z, img_size=scfg.image_size))
            stu_p, s_state, _ = d_step(stu_p, s_state, gparams, x)
            key, kz = jax.random.split(key)
            z = jax.random.normal(kz, (scfg.synth_batch, scfg.nz))
    return stu_p, student_spec


# ---------------------------------------------------------------- Fed-ADI --

def fed_adi(key, clients, scfg, student_spec: CNNSpec | None = None, *,
            adi_lr: float = 0.05, tv_coef: float = 1e-4, l2_coef: float = 1e-5,
            bn_coef: float = 1.0, refresh_every: int = 20):
    student_spec = student_spec or _student_spec(scfg)
    gspecs, gparams = stack_grouped(clients)
    k_s, key = jax.random.split(key)
    stu_p = cnn_init(k_s, student_spec)
    d_step, s_opt = make_distill_step(gspecs, student_spec, scfg)
    s_state = s_opt.init(stu_p)
    x_opt = optim.adam(adi_lr)

    @jax.jit
    def adi_step(x, xs, gparams, y):
        def loss_fn(x):
            avg, stats = grouped_ensemble_logits(
                gspecs, gparams, x, with_bn_stats=True)
            l_ce = LS.ce_loss(avg, y)
            l_bn = LS.bn_loss(stats)
            dx = jnp.diff(x, axis=1)
            dy = jnp.diff(x, axis=2)
            l_tv = jnp.mean(dx * dx) + jnp.mean(dy * dy)
            l_l2 = jnp.mean(x * x)
            return l_ce + bn_coef * l_bn + tv_coef * l_tv + l2_coef * l_l2

        loss, grads = jax.value_and_grad(loss_fn)(x)
        new_x, new_s = x_opt.update(grads, xs, x)
        return jnp.clip(new_x, -1.0, 1.0), new_s, loss

    x = None
    for epoch in range(scfg.epochs):
        if x is None or epoch % refresh_every == 0:
            key, kx, ky = jax.random.split(key, 3)
            x = jax.random.normal(kx, (scfg.synth_batch, scfg.image_size,
                                       scfg.image_size, scfg.in_ch)) * 0.5
            y = jax.random.randint(ky, (scfg.synth_batch,), 0,
                                   scfg.num_classes)
            x_state = x_opt.init(x)
        for _ in range(scfg.t_g):
            x, x_state, _ = adi_step(x, x_state, gparams, y)
        for _ in range(getattr(scfg, "s_steps", 1)):
            stu_p, s_state, _ = d_step(stu_p, s_state, gparams,
                                       jax.lax.stop_gradient(x))
    return stu_p, student_spec
