"""Client-side LocalUpdate (paper §3.1.4: SGD, lr=0.01, momentum=0.9,
b=128, E epochs; optionally LDAM [1] for imbalanced local data).

Two drivers:

  * ``local_update`` — the per-client reference: a host-side python loop
    over seeded minibatches, one jitted step per dispatch. Cost scales
    O(epochs x batches) dispatches *per client*.
  * ``local_update_grouped`` — the grouped engine: m same-architecture
    clients train as ONE compiled program. The SGD/LDAM step is batched
    over the client axis (fused im2col GEMMs for conv-stack kinds,
    ``jax.vmap`` for residual kinds — see ``group_step``) and
    ``jax.lax.scan`` walks a precomputed ``data.pipeline.BatchPlan``
    with donated carries, so the whole local phase is a single dispatch
    per group. Ragged shards are handled by masking: masked CE/LDAM
    means, masked BatchNorm batch statistics (models.cnn ``sample_mask``),
    and fully-masked padding steps that pass params/optimizer state
    through untouched. Consumes the identical per-client permutation
    stream as the python reference, so the two agree to float tolerance
    (tests/test_federation.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.core.dense import merge_bn_stats
from repro.data.pipeline import (BatchPlan, batches, bucket_members,
                                 build_batch_plan, pad_shards)
from repro.models.cnn import (CNNSpec, cnn_apply, cnn_stack_train_grouped,
                              is_conv_stack)


@functools.lru_cache(maxsize=None)
def make_local_step(spec: CNNSpec, *, lr, momentum, use_ldam=False):
    """One jitted LocalUpdate step. Cached on (spec, lr, momentum,
    use_ldam) so a python loop over same-architecture clients reuses one
    compiled step instead of recompiling per client."""
    opt = optim.sgd(lr, momentum=momentum)

    @jax.jit
    def step(params, state, x, y, margins):
        def loss_fn(p):
            logits, new_p, _ = cnn_apply(p, spec, x, train=True)
            if use_ldam:
                loss = optim.ldam_loss(logits, y, margins)
            else:
                logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
                loss = -jnp.mean(jnp.take_along_axis(logp, y[:, None], -1))
            return loss, new_p

        (loss, stats_p), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        new_p, new_state = opt.update(grads, state, params)
        new_p = merge_bn_stats(new_p, stats_p)
        return new_p, new_state, loss

    return step, opt


def local_update(params, spec: CNNSpec, x: np.ndarray, y: np.ndarray, *,
                 epochs: int, lr: float = 0.01, momentum: float = 0.9,
                 batch_size: int = 128, use_ldam: bool = False,
                 num_classes: int = 10, seed: int = 0):
    """Train a client's model on its local shard. Returns (params, info)."""
    counts = np.bincount(y, minlength=num_classes)
    margins = optim.class_margins(jnp.asarray(counts)) if use_ldam \
        else jnp.zeros((num_classes,))
    step, opt = make_local_step(spec, lr=lr, momentum=momentum,
                                use_ldam=use_ldam)
    state = opt.init(params)
    losses = []
    for bx, by in batches(x, y, batch_size, seed=seed, epochs=epochs):
        params, state, loss = step(params, state, jnp.asarray(bx),
                                   jnp.asarray(by), margins)
        losses.append(float(loss))
    return params, {"loss": losses, "class_counts": counts}


# ------------------------------------------------- grouped local update ---

@functools.lru_cache(maxsize=None)
def make_grouped_local_update(spec: CNNSpec, *, lr, momentum,
                              use_ldam=False, has_padding_steps=True):
    """Build the one-program-per-group LocalUpdate engine.

    Returns (run, opt). ``run(stacked_p, stacked_s, xs, ys, idx, mask,
    margins) -> (stacked_p, stacked_s, losses)`` where every argument
    carries a leading client axis of size m:

      stacked_p / stacked_s — params / SGD state, donated (buffers stay
        device-resident across the whole local phase);
      xs (m, n, H, W, C), ys (m, n) — padded shards (pipeline.pad_shards);
      idx / mask (m, steps, batch)  — the BatchPlan;
      margins (m, num_classes)      — per-client LDAM margins (zeros when
        use_ldam=False).

    losses is (steps, m) with zeros at fully-masked padding steps.

    has_padding_steps=False (a static property of the BatchPlan: every
    client has the group-max batches per epoch) compiles out the
    padding-step passthrough selects — partial-batch masking is
    unaffected.
    """
    opt = optim.sgd(lr, momentum=momentum)
    fused = is_conv_stack(spec.kind)

    def per_client_losses(logits, by, bmask, margins):
        """(m,) masked per-client CE/LDAM means; summing them gives every
        client its own reference gradient (params are disjoint)."""
        if use_ldam:
            return jax.vmap(
                lambda lg, yy, mg, bm: optim.ldam_loss(lg, yy, mg,
                                                       sample_mask=bm)
            )(logits, by, margins, bmask)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
        nll = -jnp.take_along_axis(logp, by[..., None], -1)[..., 0]
        w = bmask.astype(jnp.float32)
        return jnp.sum(nll * w, -1) / jnp.maximum(jnp.sum(w, -1), 1.0)

    def group_step(p, s, bx, by, bmask, margins):
        """One masked SGD/LDAM step for the whole stacked group.

        Conv-stack kinds run the fused im2col forward
        (models.cnn.cnn_stack_train_grouped): every conv is a
        client-batched GEMM whose backward is again GEMMs — on XLA CPU
        vastly faster than vmapping cnn_apply, whose batched-kernel conv
        gradients lower to the pathological grouped-convolution path.
        Residual kinds fall back to the vmapped per-client step.
        """
        def loss_fn(p_):
            if fused:
                logits, new_p, _ = cnn_stack_train_grouped(p_, spec, bx,
                                                           bmask)
            else:
                logits, new_p, _ = jax.vmap(
                    lambda pk, xk, mk: cnn_apply(pk, spec, xk, train=True,
                                                 sample_mask=mk)
                )(p_, bx, bmask)
            per = per_client_losses(logits, by, bmask, margins)
            return jnp.sum(per), (new_p, per)

        (_, (stats_p, per)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(p)
        new_p, new_s = opt.update(grads, s, p)
        new_p = merge_bn_stats(new_p, stats_p)
        if not has_padding_steps:
            return new_p, new_s, per
        # padding steps (no valid samples for client k): params AND
        # optimizer state pass through untouched — momentum must not
        # decay on steps the python reference never takes
        valid = jnp.any(bmask, -1)                  # (m,)

        def keep(a, b):
            return jnp.where(valid.reshape((-1,) + (1,) * (a.ndim - 1)),
                             a, b)

        new_p = jax.tree.map(keep, new_p, p)
        new_s = jax.tree.map(keep, new_s, s)
        return new_p, new_s, jnp.where(valid, per, 0.0)

    @functools.partial(jax.jit, donate_argnums=(0, 1))
    def run(stacked_p, stacked_s, xs, ys, idx, mask, margins):
        plan = (jnp.swapaxes(idx, 0, 1), jnp.swapaxes(mask, 0, 1))

        def body(carry, inp):
            p, s = carry
            bidx, bmask = inp                       # (m, batch) each
            bx = jax.vmap(lambda x_k, bi: x_k[bi])(xs, bidx)
            by = jax.vmap(lambda y_k, bi: y_k[bi])(ys, bidx)
            p, s, loss = group_step(p, s, bx, by, bmask, margins)
            return (p, s), loss

        (stacked_p, stacked_s), losses = jax.lax.scan(
            body, (stacked_p, stacked_s), plan)
        return stacked_p, stacked_s, losses

    return run, opt


def local_update_grouped(stacked_params, spec: CNNSpec, xs, ys,
                         plan: BatchPlan, *, lr: float = 0.01,
                         momentum: float = 0.9, use_ldam: bool = False,
                         num_classes: int = 10,
                         class_counts: np.ndarray | None = None,
                         mesh=None, policy=None):
    """Train m same-spec clients as one compiled program.

    stacked_params: client params stacked on a leading axis (DONATED —
    invalidated by the call). xs/ys: padded shards. plan: the shared
    BatchPlan. class_counts (m, num_classes): real per-shard label counts
    (required for LDAM margins; also returned in info).

    mesh: optional ("clients", "data") mesh (fl/sharding.py); when not
    given it is resolved from ``policy`` (an ExecPolicy from
    ``configs.backend.resolve_exec_policy`` — its ``ensemble_shard``
    mode routes the mesh exactly like the raw-scfg path). When the
    ``clients`` axis divides m, every leading-client-axis tensor — param
    and momentum carries, padded shards, the BatchPlan, margins — is
    placed client-sharded before the scan, so the whole local phase runs
    SPMD: the step math is per-client, so GSPMD partitions it with no
    cross-shard communication and the scan carries stay sharded across
    all steps. Placement only; the compiled math is unchanged.

    Returns (stacked_params, info) mirroring ``local_update``'s contract,
    with info["loss"] of shape (steps, m) as a device array.
    """
    if mesh is None and policy is not None:
        from repro.fl.sharding import resolve_mesh
        mesh = resolve_mesh(policy)
    m = plan.idx.shape[0]
    if class_counts is None:
        # real shard sizes recoverable from the plan: each sample appears
        # exactly once per epoch (pad_shards keeps real rows first)
        sizes = plan.mask[:, :plan.steps_per_epoch].reshape(m, -1).sum(1)
        class_counts = np.stack(
            [np.bincount(np.asarray(ys[k][:int(sizes[k])]),
                         minlength=num_classes) for k in range(m)])
    if use_ldam:
        margins = jnp.stack([optim.class_margins(jnp.asarray(c))
                             for c in class_counts])
    else:
        margins = jnp.zeros((m, num_classes))
    has_padding = bool((~plan.mask.any(-1)).any())
    run, opt = make_grouped_local_update(spec, lr=lr, momentum=momentum,
                                         use_ldam=use_ldam,
                                         has_padding_steps=has_padding)
    xs, ys = jnp.asarray(xs), jnp.asarray(ys)
    idx, mask = jnp.asarray(plan.idx), jnp.asarray(plan.mask)
    state = opt.init(stacked_params)
    if mesh is not None:
        from repro.fl.sharding import group_shardable, put_stacked
        if group_shardable(mesh, m):
            (stacked_params, state, xs, ys, idx, mask, margins) = \
                put_stacked((stacked_params, state, xs, ys, idx, mask,
                             margins), mesh, m)
    stacked_params, _, losses = run(stacked_params, state, xs, ys, idx,
                                    mask, margins)
    return stacked_params, {"loss": losses, "class_counts": class_counts}


def local_update_bucketed(make_init, spec: CNNSpec, shards, *,
                          batch_size: int, epochs: int, seeds,
                          lr: float = 0.01, momentum: float = 0.9,
                          use_ldam: bool = False, num_classes: int = 10,
                          class_counts: np.ndarray | None = None,
                          mesh=None, policy=None, bucketing: str = "off",
                          chunk: int | None = None):
    """Bucketed + chunked LocalUpdate over one architecture group
    (DESIGN.md §13): the m=1000-scale driver around
    ``local_update_grouped``.

    ``make_init(j)`` lazily materializes member j's initial params;
    ``shards``/``seeds``/``class_counts`` are per-member in group order.
    Members are first binned by batches/epoch (``pipeline.bucket_members``,
    ``bucketing``), then each bucket trains in fixed-size ``chunk``-client
    slices: per slice the host builds only O(chunk) state — the stacked
    inits, the padded shard tensor and the BatchPlan — and hands it to
    ``local_update_grouped``'s single donated-carry jitted scan. All full
    chunks of a bucket share one compiled shape (shards pad to the
    bucket's max n, plans pad to the bucket's max batches/epoch via
    ``steps_per_epoch``), so chunking costs one trace per
    (bucket-shape, chunk-size), not per chunk.

    With ``bucketing="off"`` and ``chunk`` unset this degenerates to exactly
    the single-plan, single-call path (same tensors, same jit) — the
    m=10 bit-compat boundary. With them on, per-client results stay
    BITWISE identical anyway: a client's minibatch stream never depends
    on its co-bucketed peers, padding steps pass params and momentum
    through untouched, and the per-client step math is independent of
    the stacked batch size (tests/test_scale.py pins all three claims).

    Returns the trained params stacked in ORIGINAL group member order —
    mandatory so downstream survivor masks (fl.protocol.admit_uploads)
    and per-level fedavg weights stay aligned under bucketing.
    """
    sizes = [len(y) for _, y in shards]
    size = len(shards)
    pieces, order = [], []
    for members in bucket_members(sizes, batch_size, bucketing):
        nb_bucket = max(-(-sizes[j] // batch_size) for j in members)
        pad_n = max(sizes[j] for j in members)
        step = chunk if chunk else len(members)
        for c0 in range(0, len(members), step):
            mem = members[c0:c0 + step]
            stacked0 = jax.tree.map(lambda *xs: jnp.stack(xs),
                                    *[make_init(j) for j in mem])
            xs, ys = pad_shards([shards[j] for j in mem], pad_to=pad_n)
            plan = build_batch_plan([sizes[j] for j in mem], batch_size,
                                    epochs=epochs,
                                    seeds=[seeds[j] for j in mem],
                                    steps_per_epoch=nb_bucket)
            cc = None if class_counts is None else \
                np.asarray(class_counts)[list(mem)]
            trained, _ = local_update_grouped(
                stacked0, spec, xs, ys, plan, lr=lr, momentum=momentum,
                use_ldam=use_ldam, num_classes=num_classes,
                class_counts=cc, mesh=mesh, policy=policy)
            pieces.append(trained)
            order.extend(mem)
    if len(pieces) == 1:
        stacked = pieces[0]
    else:
        # device-side concat of chunk results (never a host restack) ...
        stacked = jax.tree.map(lambda *ps: jnp.concatenate(ps, 0), *pieces)
    if list(order) != list(range(size)):
        # ... then one constant-index gather back to group member order
        perm = np.argsort(np.asarray(order, np.int64))
        stacked = jax.tree.map(lambda a: a[perm], stacked)
    return stacked


__all__ = ["make_local_step", "local_update", "make_grouped_local_update",
           "local_update_grouped", "local_update_bucketed",
           "build_batch_plan"]
