"""Client-side LocalUpdate (paper §3.1.4: SGD, lr=0.01, momentum=0.9,
b=128, E epochs; optionally LDAM [1] for imbalanced local data)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.core.dense import merge_bn_stats
from repro.data.pipeline import batches
from repro.models.cnn import CNNSpec, cnn_apply


def make_local_step(spec: CNNSpec, *, lr, momentum, use_ldam=False):
    opt = optim.sgd(lr, momentum=momentum)

    @jax.jit
    def step(params, state, x, y, margins):
        def loss_fn(p):
            logits, new_p, _ = cnn_apply(p, spec, x, train=True)
            if use_ldam:
                loss = optim.ldam_loss(logits, y, margins)
            else:
                logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
                loss = -jnp.mean(jnp.take_along_axis(logp, y[:, None], -1))
            return loss, new_p

        (loss, stats_p), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        new_p, new_state = opt.update(grads, state, params)
        new_p = merge_bn_stats(new_p, stats_p)
        return new_p, new_state, loss

    return step, opt


def local_update(params, spec: CNNSpec, x: np.ndarray, y: np.ndarray, *,
                 epochs: int, lr: float = 0.01, momentum: float = 0.9,
                 batch_size: int = 128, use_ldam: bool = False,
                 num_classes: int = 10, seed: int = 0):
    """Train a client's model on its local shard. Returns (params, info)."""
    counts = np.bincount(y, minlength=num_classes)
    margins = optim.class_margins(jnp.asarray(counts)) if use_ldam \
        else jnp.zeros((num_classes,))
    step, opt = make_local_step(spec, lr=lr, momentum=momentum,
                                use_ldam=use_ldam)
    state = opt.init(params)
    losses = []
    for bx, by in batches(x, y, batch_size, seed=seed, epochs=epochs):
        params, state, loss = step(params, state, jnp.asarray(bx),
                                   jnp.asarray(by), margins)
        losses.append(float(loss))
    return params, {"loss": losses, "class_counts": counts}
