"""FedAvg aggregation [44] — the paper's primary baseline (homogeneous
models only; Table 2 omits it for heterogeneous federations).

The aggregation itself is ONE jitted weighted tree-reduce over the
stacked client axis (``fedavg_stacked``). ``fedavg`` keeps the
list-of-clients API: when the federation was built by the grouped engine
(fl/federation.ClientList) the already-stacked group params are reduced
directly; otherwise the client trees are stacked once here.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ensemble import Client


def _check_n_data(n_data) -> np.ndarray:
    n = np.asarray(n_data, np.float64)
    if n.size == 0:
        raise ValueError("FedAvg weights are n_k / n; got an empty "
                         "n_data list")
    if np.any(n <= 0):
        # only the offending entries: interpolating all m counts is
        # unreadable at the ROADMAP's m=1000 target
        bad = [(i, v) for i, v in enumerate(np.asarray(n_data).tolist())
               if v <= 0]
        shown, extra = bad[:5], len(bad) - 5
        raise ValueError(
            "FedAvg weights are n_k / n; every client must report "
            f"n_data > 0, got (client, n_data): {shown}"
            + (f" ... and {extra} more" if extra > 0 else ""))
    return n


@jax.jit
def _weighted_reduce(stacked, w):
    """theta_S = sum_k w_k theta^k over the leading (client) axis."""
    def avg(leaf):
        wf = w.astype(jnp.float32).reshape((-1,) + (1,) * (leaf.ndim - 1))
        return jnp.sum(wf * leaf.astype(jnp.float32), 0).astype(leaf.dtype)

    return jax.tree.map(avg, stacked)


def fedavg_stacked(stacked_params, n_data, survivor_mask=None) -> dict:
    """FedAvg over params stacked on a leading client axis — the grouped
    engine's native representation. n_data: per-client example counts
    (must be positive; they define the weights n_k / n).

    survivor_mask: optional STATIC host bool mask over the client axis
    (fl.protocol admission). Survivors are sliced out with constant
    indices before the reduce — same rows, same weights, same program as
    a federation stacked without the quarantined clients, so masked
    FedAvg is bit-identical to FedAvg over the survivors
    (tests/test_faults.py). Quarantined clients' n_data never enters the
    weight normalization (and is exempt from the positivity check)."""
    if survivor_mask is not None:
        mask = np.asarray(survivor_mask, bool)
        n_all = np.asarray(n_data)
        if mask.shape != (n_all.shape[0],):
            raise ValueError(f"survivor_mask shape {mask.shape} != "
                             f"({n_all.shape[0]},)")
        if not mask.any():
            raise ValueError("FedAvg over zero surviving clients")
        idx = np.nonzero(mask)[0]
        n_data = n_all[idx]
        if not mask.all():
            stacked_params = jax.tree.map(lambda a: a[idx], stacked_params)
    n = _check_n_data(n_data)
    return _weighted_reduce(stacked_params, jnp.asarray(n / n.sum()))


def fedavg(clients: Sequence[Client]) -> dict:
    """theta_S = sum_k (n_k / n) theta^k.

    A federation that went through upload admission carries
    ``survivor_mask``; quarantined clients are excluded from the average
    (bit-identically to a federation without them)."""
    kinds = {c.spec for c in clients}
    if len(kinds) != 1:
        raise ValueError("FedAvg requires homogeneous client models; got "
                         f"{[c.spec.kind for c in clients]}")
    mask = getattr(clients, "survivor_mask", None)
    n_data = [c.n_data for c in clients]
    grouped = getattr(clients, "grouped", None)
    if grouped is not None and len(grouped[0]) == 1 \
            and grouped[0][0][1] == len(clients) and len(clients) > 1:
        # grouped-engine federation: reduce the stacked axis directly
        return fedavg_stacked(grouped[1][0], n_data, survivor_mask=mask)
    if mask is not None:
        mask = np.asarray(mask, bool)
        if not mask.any():
            raise ValueError("FedAvg over zero surviving clients")
        clients = [c for c, ok in zip(clients, mask) if ok]
        n_data = [c.n_data for c in clients]
    _check_n_data(n_data)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs),
                           *[c.params for c in clients])
    return fedavg_stacked(stacked, n_data)
