"""FedAvg aggregation [44] — the paper's primary baseline (homogeneous
models only; Table 2 omits it for heterogeneous federations).

The aggregation itself is ONE jitted weighted tree-reduce over the
stacked client axis (``fedavg_stacked``). ``fedavg`` keeps the
list-of-clients API: when the federation was built by the grouped engine
(fl/federation.ClientList) the already-stacked group params are reduced
directly; otherwise the client trees are stacked once here.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ensemble import Client


def _check_n_data(n_data) -> np.ndarray:
    n = np.asarray(n_data, np.float64)
    if n.size == 0 or np.any(n <= 0):
        raise ValueError("FedAvg weights are n_k / n; every client must "
                         f"report n_data > 0, got {list(n_data)}")
    return n


@jax.jit
def _weighted_reduce(stacked, w):
    """theta_S = sum_k w_k theta^k over the leading (client) axis."""
    def avg(leaf):
        wf = w.astype(jnp.float32).reshape((-1,) + (1,) * (leaf.ndim - 1))
        return jnp.sum(wf * leaf.astype(jnp.float32), 0).astype(leaf.dtype)

    return jax.tree.map(avg, stacked)


def fedavg_stacked(stacked_params, n_data) -> dict:
    """FedAvg over params stacked on a leading client axis — the grouped
    engine's native representation. n_data: per-client example counts
    (must be positive; they define the weights n_k / n)."""
    n = _check_n_data(n_data)
    return _weighted_reduce(stacked_params, jnp.asarray(n / n.sum()))


def fedavg(clients: Sequence[Client]) -> dict:
    """theta_S = sum_k (n_k / n) theta^k."""
    kinds = {c.spec for c in clients}
    if len(kinds) != 1:
        raise ValueError("FedAvg requires homogeneous client models; got "
                         f"{[c.spec.kind for c in clients]}")
    n_data = [c.n_data for c in clients]
    grouped = getattr(clients, "grouped", None)
    if grouped is not None and len(grouped[0]) == 1 \
            and grouped[0][0][1] == len(clients) and len(clients) > 1:
        # grouped-engine federation: reduce the stacked axis directly
        return fedavg_stacked(grouped[1][0], n_data)
    _check_n_data(n_data)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs),
                           *[c.params for c in clients])
    return fedavg_stacked(stacked, n_data)
