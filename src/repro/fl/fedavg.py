"""FedAvg aggregation [44] — the paper's primary baseline (homogeneous
models only; Table 2 omits it for heterogeneous federations)."""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.ensemble import Client


def fedavg(clients: Sequence[Client]) -> dict:
    """theta_S = sum_k (n_k / n) theta^k."""
    kinds = {c.spec for c in clients}
    if len(kinds) != 1:
        raise ValueError("FedAvg requires homogeneous client models; got "
                         f"{[c.spec.kind for c in clients]}")
    n = sum(c.n_data for c in clients)
    ws = [c.n_data / n for c in clients]

    def avg(*leaves):
        acc = sum(w * leaf.astype(jnp.float32) for w, leaf in zip(ws, leaves))
        return acc.astype(leaves[0].dtype)

    return jax.tree.map(avg, *[c.params for c in clients])
