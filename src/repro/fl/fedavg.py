"""FedAvg aggregation [44] — the paper's primary baseline (homogeneous
models only; Table 2 omits it for heterogeneous federations).

The aggregation itself is ONE jitted weighted tree-reduce over the
stacked client axis (``fedavg_stacked``). ``fedavg`` keeps the
list-of-clients API: when the federation was built by the grouped engine
(fl/federation.ClientList) the already-stacked group params are reduced
directly; otherwise the client trees are stacked once here.

Two reduction topologies (``mode``, routed from
``scfg.fedavg_mode`` through the execution-policy registry —
configs/backend.py, DESIGN.md §13):

  * ``"flat"`` (default) — one weighted sum over the full client axis.
  * ``"tree"`` — hierarchical: clients reduce in fan-in-``branch``
    groups per level, each node carrying its subtree's weighted mean and
    total n_data so every level reweights exactly (node = Σ wᵢvᵢ / Σ wᵢ
    in fp32, node weight = Σ wᵢ — the same invariant real FL
    aggregation servers keep when edge aggregators pre-combine uploads).
    The root equals the flat sum up to fp32 summation-order noise
    (tests/test_scale.py); with a ("clients", "data") mesh each shard
    tree-reduces its local clients and the cross-shard combine is a
    weighted psum pair over the ``clients`` axis.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ensemble import Client


def _check_n_data(n_data) -> np.ndarray:
    n = np.asarray(n_data, np.float64)
    if n.size == 0:
        raise ValueError("FedAvg weights are n_k / n; got an empty "
                         "n_data list")
    if np.any(n <= 0):
        # only the offending entries: interpolating all m counts is
        # unreadable at the ROADMAP's m=1000 target
        bad = [(i, v) for i, v in enumerate(np.asarray(n_data).tolist())
               if v <= 0]
        shown, extra = bad[:5], len(bad) - 5
        raise ValueError(
            "FedAvg weights are n_k / n; every client must report "
            f"n_data > 0, got (client, n_data): {shown}"
            + (f" ... and {extra} more" if extra > 0 else ""))
    return n


@jax.jit
def _weighted_reduce(stacked, w):
    """theta_S = sum_k w_k theta^k over the leading (client) axis."""
    def avg(leaf):
        wf = w.astype(jnp.float32).reshape((-1,) + (1,) * (leaf.ndim - 1))
        return jnp.sum(wf * leaf.astype(jnp.float32), 0).astype(leaf.dtype)

    return jax.tree.map(avg, stacked)


def _tree_level(v, w, branch: int):
    """One reduction level: (m, ...) values + (m,) weights -> ceil(m/b)
    weighted-mean nodes + their summed weights. The tail group is padded
    with zero-weight children; it always keeps >= 1 real child (pad <
    branch), so no node divides by zero (weights are positive —
    _check_n_data)."""
    m = v.shape[0]
    pad = (-m) % branch
    if pad:
        v = jnp.concatenate(
            [v, jnp.zeros((pad,) + v.shape[1:], v.dtype)], 0)
        w = jnp.concatenate([w, jnp.zeros((pad,), w.dtype)], 0)
    g = v.shape[0] // branch
    vg = v.reshape((g, branch) + v.shape[1:])
    wg = w.reshape(g, branch)
    wsum = jnp.sum(wg, 1)
    wf = wg.reshape((g, branch) + (1,) * (v.ndim - 1))
    node = jnp.sum(vg * wf, 1) / wsum.reshape((g,) + (1,) * (v.ndim - 1))
    return node, wsum


def _tree_reduce_leaf(leaf, w, branch: int):
    """Full trace-time tree reduce of one (m, ...) leaf to its root
    weighted mean — static level loop, fp32 accumulation throughout."""
    v, ww = leaf.astype(jnp.float32), w.astype(jnp.float32)
    while v.shape[0] > 1:
        v, ww = _tree_level(v, ww, branch)
    return v[0].astype(leaf.dtype)


@functools.partial(jax.jit, static_argnames=("branch",))
def _tree_reduce(stacked, w, branch: int):
    return jax.tree.map(lambda a: _tree_reduce_leaf(a, w, branch), stacked)


def _tree_reduce_sharded(stacked, w, branch: int, mesh):
    """Tree reduce with the client axis sharded over ``clients``: each
    shard tree-reduces its local clients to one (value, weight) node,
    then the cross-shard combine is a weighted psum pair — the mesh is
    the top level of the tree. Callers guarantee divisibility
    (fl.sharding.group_shardable)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.fl.sharding import CLIENT_AXIS

    def local(st, wl):
        def one(leaf):
            v, ww = leaf.astype(jnp.float32), wl.astype(jnp.float32)
            while v.shape[0] > 1:
                v, ww = _tree_level(v, ww, branch)
            num = jax.lax.psum(v[0] * ww[0], CLIENT_AXIS)
            den = jax.lax.psum(ww[0], CLIENT_AXIS)
            return (num / den).astype(leaf.dtype)
        return jax.tree.map(one, st)

    return shard_map(local, mesh=mesh,
                     in_specs=(P(CLIENT_AXIS), P(CLIENT_AXIS)),
                     out_specs=P(), check_rep=False)(stacked, w)


def fedavg_stacked(stacked_params, n_data, survivor_mask=None, *,
                   mode: str = "flat", branch: int = 8,
                   mesh=None) -> dict:
    """FedAvg over params stacked on a leading client axis — the grouped
    engine's native representation. n_data: per-client example counts
    (must be positive; they define the weights n_k / n).

    survivor_mask: optional STATIC host bool mask over the client axis
    (fl.protocol admission). Survivors are sliced out with constant
    indices before the reduce — same rows, same weights, same program as
    a federation stacked without the quarantined clients, so masked
    FedAvg is bit-identical to FedAvg over the survivors
    (tests/test_faults.py). Quarantined clients' n_data never enters the
    weight normalization (and is exempt from the positivity check).

    mode="tree" reduces hierarchically with fan-in ``branch`` per level
    (module docstring); with a ("clients", "data") ``mesh`` whose axis
    divides the (surviving) client count, each shard tree-reduces
    locally and the root combine is a weighted psum pair."""
    if survivor_mask is not None:
        mask = np.asarray(survivor_mask, bool)
        n_all = np.asarray(n_data)
        if mask.shape != (n_all.shape[0],):
            raise ValueError(f"survivor_mask shape {mask.shape} != "
                             f"({n_all.shape[0]},)")
        if not mask.any():
            raise ValueError("FedAvg over zero surviving clients")
        idx = np.nonzero(mask)[0]
        n_data = n_all[idx]
        if not mask.all():
            stacked_params = jax.tree.map(lambda a: a[idx], stacked_params)
    n = _check_n_data(n_data)
    w = jnp.asarray(n / n.sum())
    if mode == "tree":
        from repro.fl.sharding import group_shardable
        if group_shardable(mesh, int(w.shape[0])):
            return _tree_reduce_sharded(stacked_params, w, int(branch),
                                        mesh)
        return _tree_reduce(stacked_params, w, int(branch))
    if mode != "flat":
        raise ValueError(f"unknown fedavg mode {mode!r} "
                         "(expected 'flat' or 'tree')")
    return _weighted_reduce(stacked_params, w)


def fedavg(clients: Sequence[Client], *, policy=None, mesh=None) -> dict:
    """theta_S = sum_k (n_k / n) theta^k.

    A federation that went through upload admission carries
    ``survivor_mask``; quarantined clients are excluded from the average
    (bit-identically to a federation without them).

    policy: an ExecPolicy (configs.backend.resolve_exec_policy) routing
    the reduction topology — ``fedavg``/``fedavg_branch`` (DESIGN.md
    §13). Default is today's flat weighted sum."""
    mode = policy.fedavg if policy is not None else "flat"
    branch = policy.fedavg_branch if policy is not None else 8
    kinds = {c.spec for c in clients}
    if len(kinds) != 1:
        raise ValueError("FedAvg requires homogeneous client models; got "
                         f"{[c.spec.kind for c in clients]}")
    mask = getattr(clients, "survivor_mask", None)
    n_data = [c.n_data for c in clients]
    grouped = getattr(clients, "grouped", None)
    if grouped is not None and len(grouped[0]) == 1 \
            and grouped[0][0][1] == len(clients) and len(clients) > 1:
        # grouped-engine federation: reduce the stacked axis directly
        return fedavg_stacked(grouped[1][0], n_data, survivor_mask=mask,
                              mode=mode, branch=branch, mesh=mesh)
    if mask is not None:
        mask = np.asarray(mask, bool)
        if not mask.any():
            raise ValueError("FedAvg over zero surviving clients")
        clients = [c for c, ok in zip(clients, mask) if ok]
        n_data = [c.n_data for c in clients]
    _check_n_data(n_data)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs),
                           *[c.params for c in clients])
    return fedavg_stacked(stacked, n_data, mode=mode, branch=branch,
                          mesh=mesh)
