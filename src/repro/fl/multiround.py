"""Multi-round extension of DENSE (paper §3.3.4, Table 5).

Homogeneous clients only (the server must broadcast one global model back).
Round r: clients warm-start from the round-(r-1) global model, train E
epochs locally, upload; the server runs DENSE (student warm-started from
the previous global) and broadcasts.

Because every round's federation is homogeneous, BOTH phases hit their
grouped fast paths: the local phase trains all m clients as one
vmapped+scanned program per round (fl/federation.train_clients_grouped,
selected by ``scfg.client_loop_mode``), and the server loop evaluates all
m clients as one vmapped forward per step (core/ensemble.stack_grouped —
fed the stacked client params directly, no unstack/restack).
``scfg.loop_mode="fused"`` additionally keeps each round's E server
epochs device-resident (core/dense.py).
"""
from __future__ import annotations

import jax

from repro.core.dense import train_dense_server
from repro.core.ensemble import Client
from repro.data.partition import dirichlet_partition
from repro.fl.client import local_update
from repro.fl.federation import train_clients_grouped
from repro.fl.protocol import CommLedger, param_bytes
from repro.models.cnn import CNNSpec, cnn_init


def dense_multi_round(key, scfg, data, *, rounds: int,
                      ledger: CommLedger | None = None, eval_fn=None,
                      seed: int = 0):
    """Multi-round DENSE. With a fault plan configured (``scfg.fault_plan``
    / ``scfg.dropout_frac``), each round's uploads pass through the fault
    + admission boundary (fl/faults.py, fl.protocol.admit_uploads):
    ``delay`` faults carry a client's round-r params forward as its
    round-(r+1) upload, quarantined clients are survivor-masked out of
    that round's server ensemble, and the broadcast still reaches every
    client (the server can't know who will fault next round)."""
    from repro.configs.backend import resolve_exec_policy
    from repro.fl.faults import apply_upload_faults, build_fault_plan
    from repro.fl.protocol import admit_uploads
    from repro.fl.sharding import resolve_mesh
    pol = resolve_exec_policy(scfg)
    mode = pol.client_loop
    mesh = resolve_mesh(pol)
    x, y = data["train"]
    parts = dirichlet_partition(y, scfg.n_clients, scfg.alpha, seed=seed)
    shards = [(x[idx], y[idx]) for idx in parts] if mode == "grouped" \
        else None
    spec = CNNSpec(kind=scfg.global_kind, num_classes=scfg.num_classes,
                   in_ch=scfg.in_ch, width=scfg.width,
                   image_size=scfg.image_size)
    keys = jax.random.split(key, scfg.n_clients + rounds + 1)
    global_p = None
    accs = []
    pending: dict = {}                  # delayed uploads, one round stale
    for r in range(rounds):
        plan = build_fault_plan(scfg, round=r)
        faulty = bool(plan) or bool(pending)
        train_ledger = None if faulty else ledger
        tag = f"round{r}-model-upload"
        round_seeds = [seed * 1000 + r * 100 + i
                       for i in range(scfg.n_clients)]
        if mode == "grouped":
            clients = train_clients_grouped(
                [spec] * scfg.n_clients, shards, epochs=scfg.local_epochs,
                lr=scfg.local_lr, momentum=scfg.local_momentum,
                batch_size=scfg.batch_size, use_ldam=False,
                num_classes=scfg.num_classes, seeds=round_seeds,
                init_keys=list(keys[:scfg.n_clients]),
                init_params=None if global_p is None
                else [global_p] * scfg.n_clients,
                ledger=train_ledger, upload_tag=tag, mesh=mesh)
        else:
            clients = []
            for i, idx in enumerate(parts):
                p0 = global_p if global_p is not None \
                    else cnn_init(keys[i], spec)
                p, info = local_update(
                    p0, spec, x[idx], y[idx], epochs=scfg.local_epochs,
                    lr=scfg.local_lr, momentum=scfg.local_momentum,
                    batch_size=scfg.batch_size,
                    num_classes=scfg.num_classes, seed=round_seeds[i])
                if train_ledger is not None:
                    train_ledger.record("up", f"client{i}", param_bytes(p),
                                        tag)
                clients.append(Client(spec=spec, params=p, n_data=len(idx),
                                      class_counts=info["class_counts"]))
        if faulty:
            fault_key = jax.random.PRNGKey(
                int(getattr(scfg, "fault_seed", 0)) * 7919 + r)
            clients, arrived, pending = apply_upload_faults(
                clients, plan, key=fault_key, ledger=ledger,
                upload_tag=tag, pending=pending)
            clients = admit_uploads(clients, arrived=arrived, scfg=scfg,
                                    ledger=ledger, upload_tag=tag)
        global_p, _, _ = train_dense_server(
            keys[scfg.n_clients + r], clients, scfg, spec,
            student_params=global_p)
        if ledger is not None and r + 1 < rounds:
            for i in range(scfg.n_clients):
                ledger.record("down", f"client{i}", param_bytes(global_p),
                              f"round{r}-broadcast")
        if eval_fn is not None:
            accs.append(eval_fn(global_p, spec))
    return global_p, spec, accs
