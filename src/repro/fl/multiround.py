"""Multi-round extension of DENSE (paper §3.3.4, Table 5).

Homogeneous clients only (the server must broadcast one global model back).
Round r: clients warm-start from the round-(r-1) global model, train E
epochs locally, upload; the server runs DENSE (student warm-started from
the previous global) and broadcasts.

Because every round's federation is homogeneous, BOTH phases hit their
grouped fast paths: the local phase trains all m clients as one
vmapped+scanned program per round (fl/federation.train_clients_grouped,
selected by ``scfg.client_loop_mode``), and the server loop evaluates all
m clients as one vmapped forward per step (core/ensemble.stack_grouped —
fed the stacked client params directly, no unstack/restack).
``scfg.loop_mode="fused"`` additionally keeps each round's E server
epochs device-resident (core/dense.py).
"""
from __future__ import annotations

import jax

from repro.core.dense import train_dense_server
from repro.core.ensemble import Client
from repro.data.partition import dirichlet_partition
from repro.fl.client import local_update
from repro.fl.federation import train_clients_grouped
from repro.fl.protocol import CommLedger, param_bytes
from repro.models.cnn import CNNSpec, cnn_init


def dense_multi_round(key, scfg, data, *, rounds: int,
                      ledger: CommLedger | None = None, eval_fn=None,
                      seed: int = 0):
    from repro.fl.sharding import resolve_mesh
    mode = getattr(scfg, "client_loop_mode", "grouped")
    if mode not in ("python", "grouped"):
        raise ValueError(f"unknown client_loop_mode {mode!r} "
                         "(expected 'python' or 'grouped')")
    mesh = resolve_mesh(scfg)
    x, y = data["train"]
    parts = dirichlet_partition(y, scfg.n_clients, scfg.alpha, seed=seed)
    shards = [(x[idx], y[idx]) for idx in parts] if mode == "grouped" \
        else None
    spec = CNNSpec(kind=scfg.global_kind, num_classes=scfg.num_classes,
                   in_ch=scfg.in_ch, width=scfg.width,
                   image_size=scfg.image_size)
    keys = jax.random.split(key, scfg.n_clients + rounds + 1)
    global_p = None
    accs = []
    for r in range(rounds):
        round_seeds = [seed * 1000 + r * 100 + i
                       for i in range(scfg.n_clients)]
        if mode == "grouped":
            clients = train_clients_grouped(
                [spec] * scfg.n_clients, shards, epochs=scfg.local_epochs,
                lr=scfg.local_lr, momentum=scfg.local_momentum,
                batch_size=scfg.batch_size, use_ldam=False,
                num_classes=scfg.num_classes, seeds=round_seeds,
                init_keys=list(keys[:scfg.n_clients]),
                init_params=None if global_p is None
                else [global_p] * scfg.n_clients,
                ledger=ledger, upload_tag=f"round{r}-model-upload",
                mesh=mesh)
        else:
            clients = []
            for i, idx in enumerate(parts):
                p0 = global_p if global_p is not None \
                    else cnn_init(keys[i], spec)
                p, info = local_update(
                    p0, spec, x[idx], y[idx], epochs=scfg.local_epochs,
                    lr=scfg.local_lr, momentum=scfg.local_momentum,
                    batch_size=scfg.batch_size,
                    num_classes=scfg.num_classes, seed=round_seeds[i])
                if ledger is not None:
                    ledger.record("up", f"client{i}", param_bytes(p),
                                  f"round{r}-model-upload")
                clients.append(Client(spec=spec, params=p, n_data=len(idx),
                                      class_counts=info["class_counts"]))
        global_p, _, _ = train_dense_server(
            keys[scfg.n_clients + r], clients, scfg, spec,
            student_params=global_p)
        if ledger is not None and r + 1 < rounds:
            for i in range(scfg.n_clients):
                ledger.record("down", f"client{i}", param_bytes(global_p),
                              f"round{r}-broadcast")
        if eval_fn is not None:
            accs.append(eval_fn(global_p, spec))
    return global_p, spec, accs
