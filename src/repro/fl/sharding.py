"""Client-axis mesh sharding: one SPMD vocabulary for every stacked-client
computation, from grouped local training to the ensemble teacher.

The grouped engine (fl/federation.py) and the grouped ensemble
(core/ensemble.stack_grouped) both hold a federation as per-architecture
pytrees stacked along a leading client dim of size m. This module is the
single place that maps that dim onto a mesh:

  * ``launch.mesh.make_client_mesh`` builds the ("clients", "data") mesh;
    ``resolve_mesh(scfg)`` routes it from ``scfg.ensemble_shard_mode``
    ("none" -> single-device, "clients" -> shard the client axis).
  * ``client_stack_sharding`` / ``put_stacked`` place an (m, ...) stack
    with the leading dim split over ``clients`` — used for param and
    momentum carries AND for the (m, steps, batch) batch-plan tensors, so
    grouped local training is SPMD by placement alone (GSPMD propagates
    the client axis through the vmapped step; per-client math never
    crosses shards).
  * ``stack_specs`` prepends a stacked-client axis to an existing
    PartitionSpec tree — the shared vocabulary between this host path and
    ``core.dense_llm``'s pod-sharded cell, whose ensemble dim is the same
    leading client dim under the name "pod".
  * ``core.ensemble.grouped_ensemble_logits(..., mesh=...)`` lowers the
    logit mean to per-shard partial sums + ONE ``psum`` over ``clients``
    via ``shard_map`` (DESIGN.md §8).

A group only shards when its size is divisible by the ``clients`` axis
(``group_shardable``); otherwise it is placed replicated and the existing
single-device vmap path runs unchanged — ``ensemble_shard_mode="clients"``
is therefore always correctness-safe, on any device count. Equivalence is
exercised on CPU via ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(tests/test_client_sharding.py, CI job ``sharding-equivalence``).
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.backend import SHARD_MODES, resolve_exec_policy
from repro.launch.mesh import make_client_mesh

CLIENT_AXIS = "clients"


def resolve_mesh(scfg):
    """Mesh routing for the CNN-scale host path: None (single-device)
    or the ("clients", "data") host mesh. ``scfg`` may be a config, an
    already-resolved ExecPolicy, or None — the shard mode comes from the
    backend execution-policy registry (configs/backend.py, DESIGN.md
    §11; "none" on every backend unless ``scfg.ensemble_shard_mode``
    opts in)."""
    mode = resolve_exec_policy(scfg).ensemble_shard
    if mode == "none":
        return None
    return make_client_mesh()


def client_axis_size(mesh) -> int:
    if mesh is None:
        return 1
    return int(dict(mesh.shape).get(CLIENT_AXIS, 1))


def group_shardable(mesh, size: int) -> bool:
    """A stacked group shards iff the clients axis divides its size (each
    shard then carries size // axis whole clients)."""
    return mesh is not None and size > 1 \
        and size % client_axis_size(mesh) == 0


def stack_specs(inner_specs, axis):
    """Prepend a stacked-client axis to an existing PartitionSpec tree.

    The shared spec vocabulary between the host and pod paths: the host
    CNN stacks use axis="clients" over replicated inner specs; the LLM
    pod cell (core.dense_llm.pod_stack_specs) prepends axis="pod" to its
    Megatron param specs. axis=None yields a replicated leading dim.
    """
    return jax.tree.map(lambda s: P(axis, *s), inner_specs,
                        is_leaf=lambda x: isinstance(x, P))


def client_stack_sharding(mesh) -> NamedSharding:
    """Leading client dim over ``clients``; everything else replicated."""
    return NamedSharding(mesh, P(CLIENT_AXIS))


def replicated_sharding(mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def put_stacked(tree, mesh, size: int):
    """Place a leading-client-axis stacked pytree on the mesh: sharded
    over ``clients`` when the group size divides, else replicated."""
    if mesh is None:
        return tree
    sh = client_stack_sharding(mesh) if group_shardable(mesh, size) \
        else replicated_sharding(mesh)
    return jax.device_put(tree, sh)


def put_replicated(tree, mesh):
    if mesh is None:
        return tree
    return jax.device_put(tree, replicated_sharding(mesh))


def put_grouped(gspecs, gparams, mesh):
    """Place a grouped-ensemble representation (ensemble.stack_grouped):
    each stacked group client-sharded when divisible, singletons and
    ragged groups replicated."""
    if mesh is None:
        return gparams
    return [put_replicated(params, mesh) if size == 1
            else put_stacked(params, mesh, size)
            for (_, size), params in zip(gspecs, gparams)]


__all__ = ["CLIENT_AXIS", "SHARD_MODES", "resolve_mesh", "client_axis_size",
           "group_shardable", "stack_specs", "client_stack_sharding",
           "replicated_sharding", "put_stacked", "put_replicated",
           "put_grouped"]
