"""Grouped client-training engine: the federation's local-update phase,
owned end-to-end — partition -> batch plan -> grouped training -> upload.

DENSE's one communication round (§3.1.4 LocalUpdate) used to be simulated
one client at a time: a python loop over m clients, each a python loop
over epochs x batches jitted steps. This module groups clients by
architecture (the same move core/ensemble.py makes for the *server's*
view) and trains each group as ONE compiled program:

  * ``data.pipeline.build_batch_plan`` precomputes every client's seeded
    minibatch schedule as one padded (m, steps, batch) index tensor with
    a validity mask;
  * ``fl.client.local_update_grouped`` vmaps the masked SGD/LDAM step
    over the client axis and scans the plan with donated carries;
  * the trained stacked params become the grouped-ensemble representation
    *directly*: ``ClientList.grouped`` hands (gspecs, gparams) to
    ``core.ensemble.stack_grouped`` with no unstack/restack through host
    memory, and ``fl.fedavg.fedavg`` reduces the same stacked axis;
  * with ``scfg.ensemble_shard_mode="clients"`` (fl/sharding.py) each
    group's stacked carries and batch-plan tensors are placed with the
    client axis sharded over the ("clients", "data") mesh, so the whole
    local phase is SPMD — placement only, identical math.

Per-client ``Client`` views (materialized once per client by slicing the
stacked arrays — grouped consumers never touch them, but per-client
evaluation, FedAvg's listwise fallback and the equivalence tests do)
keep the original list-of-clients API working for everything downstream.
"""
from __future__ import annotations

from typing import Sequence

import jax
import numpy as np

from repro.core.ensemble import Client
from repro.data.partition import dirichlet_partition
from repro.fl.client import local_update_bucketed
from repro.models.cnn import CNNSpec, cnn_init


class ClientList(list):
    """Per-client ``Client`` views + the grouped stacked representation.

    ``grouped`` is a (gspecs, gparams) pair in ``stack_grouped``'s exact
    contract — a tuple of (CNNSpec, group_size) plus one params pytree
    per group (stacked leading client axis for groups of size > 1, flat
    for singletons). ``stack_grouped`` returns it as-is, so the params
    trained by the grouped engine flow into the server's ensemble without
    a round trip through per-client trees.
    """

    def __init__(self, clients: Sequence[Client], gspecs, gparams):
        super().__init__(clients)
        self.grouped = (tuple(gspecs), gparams)


def client_specs(scfg) -> list[CNNSpec]:
    """The federation's client architectures (scfg.client_kinds cycled)."""
    return [CNNSpec(kind=scfg.client_kinds[i % len(scfg.client_kinds)],
                    num_classes=scfg.num_classes, in_ch=scfg.in_ch,
                    width=scfg.width, image_size=scfg.image_size)
            for i in range(scfg.n_clients)]


def group_specs(specs: Sequence[CNNSpec]):
    """Group client indices by architecture, first-occurrence ordered —
    the spec-level analogue of ``core.ensemble.group_clients``."""
    groups: dict[CNNSpec, list[int]] = {}
    for i, spec in enumerate(specs):
        groups.setdefault(spec, []).append(i)
    return [(spec, tuple(idx)) for spec, idx in groups.items()]


def train_clients_grouped(specs: Sequence[CNNSpec], shards: Sequence[tuple],
                          *, epochs: int, lr: float, momentum: float,
                          batch_size: int, use_ldam: bool, num_classes: int,
                          seeds: Sequence[int],
                          init_keys: Sequence | None = None,
                          init_params: Sequence[dict] | None = None,
                          n_data: Sequence[int] | None = None,
                          ledger=None,
                          upload_tag: str = "round0-model-upload",
                          mesh=None, policy=None) -> ClientList:
    """Run the grouped LocalUpdate phase over an arbitrary federation.

    specs/shards/seeds are per-client (federation order). Initial params
    come from ``init_params[i]`` when given (multi-round warm starts),
    else ``cnn_init(init_keys[i], spec)`` — the same per-client keys the
    python reference uses, so both paths start identically. Records one
    'up' ledger event per client with that client's byte count (the
    one-shot property — m uploads, zero broadcasts — is preserved under
    grouped training). mesh: optional ("clients", "data") mesh; each
    group whose size the ``clients`` axis divides trains client-sharded
    (fl.client.local_update_grouped).

    policy: an ExecPolicy (``configs.backend.resolve_exec_policy``)
    routing the federation-scale knobs: ``bucketing`` bins each group by
    batches/epoch before padding and ``stack_chunk`` streams each bucket
    through fixed-size chunks so group setup peaks at O(chunk) host
    memory (fl.client.local_update_bucketed, DESIGN.md §13). The stacked
    group params are always reassembled in original group member order,
    so survivor masks and fedavg weights compose with buckets unchanged.
    With the knobs off (every registry default) the path is bitwise the
    unbucketed single-program engine.
    """
    from repro.fl.protocol import param_bytes   # lazy: protocol routes here
    m = len(specs)
    assert init_params is not None or init_keys is not None
    if n_data is None:
        n_data = [len(y) for _, y in shards]
    bucketing = policy.bucketing if policy is not None else "off"
    stack_chunk = policy.stack_chunk if policy is not None else 0
    groups = group_specs(specs)
    gspecs = [(spec, len(idx)) for spec, idx in groups]
    gparams: list = []
    params_view: list = [None] * m
    counts_view: list = [None] * m
    for spec, idx in groups:
        group_shards = [shards[i] for i in idx]
        counts = np.stack([np.bincount(y, minlength=num_classes)
                           for _, y in group_shards])

        def make_init(j, _spec=spec, _idx=idx):
            return init_params[_idx[j]] if init_params is not None \
                else cnn_init(init_keys[_idx[j]], _spec)

        trained = local_update_bucketed(
            make_init, spec, group_shards, batch_size=batch_size,
            epochs=epochs, seeds=[seeds[i] for i in idx], lr=lr,
            momentum=momentum, use_ldam=use_ldam, num_classes=num_classes,
            class_counts=counts, mesh=mesh, policy=policy,
            bucketing=bucketing, chunk=stack_chunk)
        size = len(idx)
        if size == 1:
            trained = jax.tree.map(lambda a: a[0], trained)
            gparams.append(trained)
            params_view[idx[0]] = trained
        else:
            gparams.append(trained)
            for j, i in enumerate(idx):
                params_view[i] = jax.tree.map(lambda a, _j=j: a[_j], trained)
        for j, i in enumerate(idx):
            counts_view[i] = counts[j]
        if ledger is not None:
            per_client_bytes = param_bytes(gparams[-1]) // size
            for i in idx:
                ledger.record("up", f"client{i}", per_client_bytes,
                              upload_tag)
    clients = [Client(spec=specs[i], params=params_view[i],
                      n_data=int(n_data[i]), class_counts=counts_view[i])
               for i in range(m)]
    return ClientList(clients, gspecs, gparams)


def build_grouped_federation(key, scfg, data, *, ledger=None, seed: int = 0):
    """Grouped-engine drop-in for ``fl.protocol.build_federation``:
    Dirichlet partition, grouped local training, one upload per client.

    Returns (clients, shards) with clients a ``ClientList`` whose
    ``grouped`` representation feeds ``stack_grouped`` directly. Uses the
    same per-client init keys and batch seeds as the python reference, so
    the two paths agree to float tolerance.
    ``scfg.ensemble_shard_mode="clients"`` trains each (divisible) group
    sharded over the ("clients", "data") mesh — same seeds, same math.
    """
    from repro.configs.backend import resolve_exec_policy
    from repro.fl.sharding import resolve_mesh
    pol = resolve_exec_policy(scfg)
    x, y = data["train"]
    parts = dirichlet_partition(y, scfg.n_clients, scfg.alpha, seed=seed)
    shards = [(x[idx], y[idx]) for idx in parts]
    specs = client_specs(scfg)
    keys = jax.random.split(key, scfg.n_clients)
    clients = train_clients_grouped(
        specs, shards, epochs=scfg.local_epochs, lr=scfg.local_lr,
        momentum=scfg.local_momentum, batch_size=scfg.batch_size,
        use_ldam=scfg.use_ldam, num_classes=scfg.num_classes,
        seeds=[seed + i for i in range(scfg.n_clients)],
        init_keys=list(keys), ledger=ledger, mesh=resolve_mesh(pol),
        policy=pol)
    return clients, shards


__all__ = ["ClientList", "client_specs", "group_specs",
           "train_clients_grouped", "build_grouped_federation"]
