"""The paper's own experimental setting (Section 3.1) as a config object.

Not an LLM architecture — this drives the faithful DENSE reproduction on
CNN clients (ResNet-18 / CNN1 / CNN2 / WRN-16-1 / WRN-40-1, Table 2) with
Dirichlet non-IID partitioning.
"""
from dataclasses import dataclass, field


@dataclass(frozen=True)
class DenseExperimentConfig:
    # federation (paper defaults, §3.1.4)
    n_clients: int = 5
    alpha: float = 0.5              # Dirichlet concentration
    local_epochs: int = 200
    local_lr: float = 0.01
    local_momentum: float = 0.9
    batch_size: int = 128
    use_ldam: bool = False

    # data (procedural stand-in for CIFAR10 — see DESIGN.md §2)
    num_classes: int = 10
    image_size: int = 32
    in_ch: int = 3
    train_per_class: int = 512
    test_per_class: int = 128

    # client model zoo ("resnet18" homogeneous by default; Table 2 uses the
    # heterogeneous list)
    client_kinds: tuple = ("resnet18",) * 5
    global_kind: str = "resnet18"
    width: float = 1.0

    # DENSE server (Algorithm 1)
    nz: int = 100                   # generator latent dim
    g_lr: float = 1e-3              # Adam, eta_G
    s_lr: float = 0.01              # SGD, eta_S
    s_momentum: float = 0.9
    t_g: int = 30                   # generator inner steps per epoch
    epochs: int = 200               # T (distillation epochs)
    synth_batch: int = 128
    lambda_bn: float = 1.0          # lambda_1
    lambda_div: float = 0.5         # lambda_2
    comm_rounds: int = 1            # one-shot; >1 = §3.3.4 extension
    s_steps: int = 1                # student steps per epoch. 1 = Algorithm 1
                                    # verbatim; >1 draws fresh noise per step
                                    # (all baselines get the same budget).
    # Execution-mode knobs. None (the default) defers to the backend
    # execution-policy registry (configs/backend.py, DESIGN.md §11),
    # which picks per-backend defaults (cpu: python/ref; gpu/tpu:
    # fused/fused) — set a knob to pin a mode regardless of backend.
    # Resolution happens ONLY through
    # ``configs.backend.resolve_exec_policy(scfg)``.
    backend: str | None = None      # "cpu" | "gpu" | "tpu"; None →
                                    # REPRO_BACKEND env, then
                                    # jax.default_backend().
    loop_mode: str | None = None    # epoch driver: "python" (per-step
                                    # jit) or "fused" (device-resident
                                    # lax.scan chunks — core/dense.py).
    loop_chunk: int = 8             # epochs per fused scan program
    client_loop_mode: str | None = None  # LocalUpdate driver: "grouped"
                                    # (one vmapped+scanned program per
                                    # architecture group — fl/federation)
                                    # or "python" (per-client reference
                                    # loop; equivalence ground truth).
    ensemble_shard_mode: str | None = None  # stacked-client-axis
                                    # placement: "none" (single-device)
                                    # or "clients" (shard the leading
                                    # client dim of every stacked
                                    # computation — local training AND
                                    # the ensemble teacher — over the
                                    # ("clients", "data") mesh;
                                    # fl/sharding.py, DESIGN.md §8).
                                    # Registry default is "none" on
                                    # every backend: sharding is a
                                    # topology choice, not a backend
                                    # choice.
    distill_kl_mode: str | None = None  # stage-2 KL implementation:
                                    # "ref" (materialized jnp
                                    # log-softmax + autodiff) or
                                    # "fused" (Pallas custom-VJP kernel
                                    # pair streaming vocab blocks in
                                    # both passes; kernels/distill_kl,
                                    # DESIGN.md §9).
    kernel_blocks: tuple = ()       # explicit per-kernel block-shape
                                    # overrides, e.g.
                                    # (("distill_kl", (128, 1024)),);
                                    # unset kernels use the registry
                                    # table / autotuner cache
                                    # (configs/backend.py).

    # — federation-scale knobs (DESIGN.md §13). All default to the
    # registry's bit-compat-off setting on every backend; enabling any
    # of them changes memory/padding behavior but the per-client
    # minibatch stream and (for chunking) the trained params are
    # contract-tested identical (tests/test_scale.py).
    plan_bucketing: str | None = None  # batch-plan bucketing before
                                    # padding: "off" (one plan per arch
                                    # group, padded to the slowest
                                    # client), "pow2" (bin clients by
                                    # next-pow2 steps/epoch; waste < 2x)
                                    # or "quantile" (4 quantile bins of
                                    # the steps/epoch distribution).
    stack_chunk: int | None = None  # clients per host-side stacking /
                                    # training chunk (0 = whole group):
                                    # group setup peaks at O(chunk) host
                                    # memory instead of O(m).
    fedavg_mode: str | None = None  # "flat" (one global weighted sum)
                                    # or "tree" (hierarchical reduce
                                    # with per-level n_data reweighting;
                                    # fp32-accumulated, shardable over
                                    # the "clients" mesh axis).
    fedavg_branch: int | None = None  # tree-reduce fan-in per level
                                    # (>= 2; registry default 8).
    teacher_chunk: int | None = None  # clients per ensemble-teacher
                                    # scan chunk (0 = off): the stage-2
                                    # teacher streams sub-group logit
                                    # partial sums instead of
                                    # materializing (m, B, C).

    # fault tolerance (DESIGN.md §10) — injection knobs (fl/faults.py):
    fault_plan: tuple = ()          # explicit per-client faults, entries
                                    # are Fault or (client, kind[, scale
                                    # [, round]]) tuples; kinds: drop,
                                    # delay, nan, inf, noise, signflip
    dropout_frac: float = 0.0       # fraction of clients whose upload is
                                    # dropped per round (seeded choice)
    fault_seed: int = 0             # seeds dropout choice + corruption

    # — admission/defense knobs (fl.protocol.admit_uploads):
    upload_policy: str = "quarantine"  # failed screen: "quarantine"
                                    # (survivor-masked exclusion) or
                                    # "strict" (raise UploadError)
    quorum: float = 0.5             # min surviving fraction; below it
                                    # the round aborts with QuorumError
    norm_screen: float = 0.0        # param-norm outlier screen in MADs
                                    # (0 = off; cohorts >= 5 only)
    cos_screen: float | None = None  # direction screen: min cosine of
                                    # each upload to its leave-one-out
                                    # cohort mean (None = off; cohorts
                                    # >= 5 only). Closes the
                                    # norm-preserving `signflip` gap the
                                    # MAD screen cannot see (a flipped
                                    # upload has cosine ~ -1 to the
                                    # cohort it trained with). Assumes
                                    # cohort models cluster
                                    # directionally — true for trained
                                    # uploads from similar data, NOT
                                    # for raw random inits.

    # — stage-2 self-healing (core/dense.py):
    nan_policy: str = "raise"       # non-finite server loss: "raise",
                                    # "skip" (compiled no-op step) or
                                    # "rollback" (last good snapshot)
    checkpoint_every: int = 0       # server-state checkpoint period in
                                    # epochs (0 = off)
    checkpoint_path: str = ""       # npz path stem (checkpoint/io.py);
                                    # restored on entry if present
    seed: int = 0


CONFIG = DenseExperimentConfig()


def smoke() -> DenseExperimentConfig:
    """CPU-sized setting used by tests/benchmarks (relative claims only)."""
    return DenseExperimentConfig(
        n_clients=3, local_epochs=8, batch_size=64, train_per_class=96,
        test_per_class=32, image_size=16,
        client_kinds=("cnn1", "cnn1", "cnn1"), global_kind="cnn1",
        width=0.5, t_g=5, epochs=20, synth_batch=64, nz=32)
