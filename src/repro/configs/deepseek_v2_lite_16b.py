"""deepseek-v2-lite-16b [moe] — MLA (kv_lora=512, no q_lora) + MoE 64e top-6.

Source: [arXiv:2405.04434]: 27L d_model=2048 16H d_ff_expert=1408
vocab=102400, 2 shared experts, first layer dense.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b", family="moe", source="arXiv:2405.04434",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=11264, vocab_size=102400,
    n_experts=64, n_shared_experts=2, top_k=6, d_ff_expert=1408,
    first_dense=True, kv_lora_rank=512, q_lora_rank=0,
    qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
    max_seq_len=131_072,
)


def smoke() -> ArchConfig:
    return CONFIG.replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=192, vocab_size=512, n_experts=4, n_shared_experts=1, top_k=2,
        d_ff_expert=64, kv_lora_rank=32, q_lora_rank=0,
        qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
        dtype="float32", param_dtype="float32", remat=False)
