"""Backend-aware execution-policy registry (DESIGN.md §11).

One resolution API for every mode knob. Historically the repo carried
four execution-mode knobs (``loop_mode``, ``ensemble_shard_mode``,
``distill_kl_mode``, ``kernel_vjp_mode`` — plus ``client_loop_mode``)
that each defaulted to CPU-friendly settings with "flip when an
accelerator lands" folklore in their comments, and hardcoded Pallas
block shapes threaded as per-call kwargs through the kernel wrappers.
This module is now the ONLY place those decisions are made:

  * ``resolve_exec_policy(scfg)`` — scfg knobs (when set) override the
    per-backend registry defaults; the result is a frozen, hashable
    ``ExecPolicy`` consumed by core/dense.py, core/dense_llm.py,
    launch/steps.py, fl/protocol.py, fl/sharding.py, fl/client.py and
    kernels/ops.py. A grep-enforcement test (tests/test_backend.py)
    bans raw knob reads and literal block-shape kwargs everywhere else.
  * ``arch_policy(cfg)`` — the model-layer variant: ArchConfig's
    ``kernel_vjp_mode`` / ``attn_block_q`` / ``attn_block_kv`` /
    ``ssm_chunk`` become explicit overrides on the registry policy.
  * a lightweight autotuner that times candidate block shapes for the
    three kernel pairs at first trace and caches the winner per
    ``(backend, kernel, shape-bucket)`` in an on-disk JSON cache with
    deterministic tie-breaking (earliest candidate wins ties).

Backend detection precedence: ``scfg.backend`` > ``REPRO_BACKEND`` env
> ``jax.default_backend()``. Interpret-mode: from the registry
(cpu → True, gpu/tpu → False), overridable by ``REPRO_INTERPRET``
("1"/"0") — this also fixes the old ``_auto_interpret`` bug where a GPU
backend silently ran every kernel in interpret mode. ``REPRO_AUTOTUNE=1``
enables timing on cache miss; ``REPRO_AUTOTUNE_CACHE`` points the
writable cache somewhere else (default ``~/.cache/repro-dense/
autotune.json``). A committed seed cache (configs/autotune_seed.json)
is always loaded first so CI timing noise never changes selected blocks.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
import warnings
from dataclasses import dataclass

BACKENDS = ("cpu", "gpu", "tpu")

# mode vocabularies (single source of truth; fl/sharding, core/losses and
# kernels/ops re-export their historical names for compatibility)
LOOP_MODES = ("python", "fused")
CLIENT_LOOP_MODES = ("python", "grouped")
SHARD_MODES = ("none", "clients")
KL_MODES = ("ref", "fused")
KERNEL_VJP_MODES = ("ref", "autodiff", "fused")
BUCKETING_MODES = ("off", "pow2", "quantile")
FEDAVG_MODES = ("flat", "tree")

# the three custom-VJP kernel pairs and their block-shape argument names,
# in canonical order (DESIGN.md §9), plus the forward-only serving
# kernel (§12; its "page" is the block-pool page size — a cache *layout*
# parameter consumed at allocation time by launch/paging.py, not a
# per-call kwarg). The ``*_bwd`` entries tune the BACKWARD kernel of a
# pair separately from its forward (DESIGN.md §13): distill_kl's
# backward is embarrassingly parallel where its forward is a sequential
# vocab sweep, and flash-attention's dq/dkv streams have different
# residency than the forward's online softmax — the same block winner
# rarely serves both directions. ssd_scan has NO ``_bwd`` entry by
# construction: its residual contract snapshots carried states at
# *forward* chunk boundaries, so the backward must walk the identical
# chunk grid (a separate bwd chunk would misalign the snapshots).
KERNEL_BLOCK_ARGS = {
    "distill_kl": ("block_rows", "block_v"),
    "distill_kl_bwd": ("block_rows", "block_v"),
    "flash_attention": ("block_q", "block_k"),
    "flash_attention_bwd": ("block_q", "block_k"),
    "ssd_scan": ("chunk",),
    "paged_attention": ("page",),
}

# per-backend default execution modes. ensemble_shard stays "none" on
# every backend: sharding is a topology choice (how many devices carry
# the client axis), not a backend choice — opt in per-scfg. The same
# reasoning pins the federation-scale knobs (DESIGN.md §13) to their
# bit-compat-off settings on every backend: bucketing/chunking/tree
# reduction are *federation-size* choices (m=1000 wants them, m=10
# must stay bitwise-identical to the unchunked path), so scenarios
# opt in per-scfg rather than inheriting them from the hardware.
_SCALE_DEFAULTS = {"bucketing": "off", "stack_chunk": 0,
                   "fedavg": "flat", "fedavg_branch": 8,
                   "teacher_chunk": 0}
_PROFILES = {
    "cpu": {"loop": "python", "client_loop": "grouped",
            "ensemble_shard": "none", "distill_kl": "ref",
            "kernel_vjp": "ref", "interpret": True, **_SCALE_DEFAULTS},
    "gpu": {"loop": "fused", "client_loop": "grouped",
            "ensemble_shard": "none", "distill_kl": "fused",
            "kernel_vjp": "fused", "interpret": False, **_SCALE_DEFAULTS},
    "tpu": {"loop": "fused", "client_loop": "grouped",
            "ensemble_shard": "none", "distill_kl": "fused",
            "kernel_vjp": "fused", "interpret": False, **_SCALE_DEFAULTS},
}

# per-backend default block shapes. The cpu row reproduces the historical
# hardcoded kwargs exactly; accelerator rows start from the same values
# and are refined by the autotuner cache, not by code edits.
_BLOCKS = {
    "cpu": {"distill_kl": (256, 2048), "distill_kl_bwd": (256, 2048),
            "flash_attention": (128, 128), "flash_attention_bwd": (128, 128),
            "ssd_scan": (128,), "paged_attention": (16,)},
    "gpu": {"distill_kl": (256, 2048), "distill_kl_bwd": (256, 2048),
            "flash_attention": (128, 128), "flash_attention_bwd": (128, 128),
            "ssd_scan": (128,), "paged_attention": (16,)},
    "tpu": {"distill_kl": (256, 1024), "distill_kl_bwd": (256, 1024),
            "flash_attention": (256, 256), "flash_attention_bwd": (256, 256),
            "ssd_scan": (256,), "paged_attention": (128,)},
}

# autotuner candidate block shapes, in canonical order — ties between
# equally-timed candidates break toward the EARLIEST entry, so this
# order is part of the determinism contract. The ``*_bwd`` candidate
# lists mirror the forward's; their thunks time the standalone backward
# kernel (distill_kl_bwd / flash_attention_bwd) on precomputed forward
# residuals, so a bwd winner reflects only backward-stream cost.
_CANDIDATES = {
    "distill_kl": ((256, 2048), (128, 1024), (64, 512), (32, 256)),
    "distill_kl_bwd": ((256, 2048), (128, 1024), (64, 512), (32, 256)),
    "flash_attention": ((128, 128), (64, 64), (32, 32)),
    "flash_attention_bwd": ((128, 128), (64, 64), (32, 32)),
    "ssd_scan": ((128,), (64,), (32,)),
    "paged_attention": ((16,), (32,), (64,)),
}

_SEED_CACHE = os.path.join(os.path.dirname(__file__), "autotune_seed.json")
_CACHE_VERSION = 1


def check_loop_mode(mode):
    if mode not in LOOP_MODES:
        raise ValueError(f"unknown loop_mode {mode!r} "
                         "(expected 'python' or 'fused')")


def check_client_loop_mode(mode):
    if mode not in CLIENT_LOOP_MODES:
        raise ValueError(f"unknown client_loop_mode {mode!r} "
                         "(expected 'python' or 'grouped')")


def check_shard_mode(mode):
    if mode not in SHARD_MODES:
        raise ValueError(f"unknown ensemble_shard_mode {mode!r} "
                         f"(expected one of {SHARD_MODES})")


def check_kl_mode(mode):
    if mode not in KL_MODES:
        raise ValueError(f"unknown distill_kl mode {mode!r} "
                         f"(expected one of {KL_MODES})")


def check_kernel_vjp_mode(mode):
    if mode not in KERNEL_VJP_MODES:
        raise ValueError(f"unknown kernel_vjp mode {mode!r} "
                         f"(expected one of {KERNEL_VJP_MODES})")


def check_bucketing_mode(mode):
    if mode not in BUCKETING_MODES:
        raise ValueError(f"unknown plan_bucketing {mode!r} "
                         f"(expected one of {BUCKETING_MODES})")


def check_fedavg_mode(mode):
    if mode not in FEDAVG_MODES:
        raise ValueError(f"unknown fedavg_mode {mode!r} "
                         f"(expected one of {FEDAVG_MODES})")


def check_chunk_size(name, value):
    """Chunk knobs are non-negative ints; 0 disables chunking."""
    if int(value) != value or int(value) < 0:
        raise ValueError(f"{name} must be a non-negative int, "
                         f"got {value!r}")


def check_fedavg_branch(value):
    if int(value) != value or int(value) < 2:
        raise ValueError(f"fedavg_branch must be an int >= 2, "
                         f"got {value!r}")


def detect_backend(scfg=None) -> str:
    """scfg.backend > REPRO_BACKEND env > jax.default_backend()."""
    b = getattr(scfg, "backend", None)
    if b is None:
        b = os.environ.get("REPRO_BACKEND") or None
    if b is None:
        import jax
        b = jax.default_backend()
    b = str(b).lower()
    if b not in BACKENDS:
        raise ValueError(f"unknown backend {b!r} "
                         f"(expected one of {BACKENDS})")
    return b


# ------------------------------------------------------------ ExecPolicy

@dataclass(frozen=True)
class ExecPolicy:
    """Frozen, hashable resolution of every execution decision.

    Field names are deliberately SHORT (``loop``, not ``loop_mode``):
    the grep-enforcement test bans the long knob names outside configs/,
    and policy reads must not trip it.

    ``blocks`` is the registry default table, ``tuned`` the autotuner
    cache entries for this backend, ``overrides`` explicit per-scfg /
    per-arch choices — ``blocks_for`` applies them in increasing
    precedence. All three are nested tuples so the policy hashes (it is
    used as a jit-static value and as a cache key).
    """
    backend: str = "cpu"
    loop: str = "python"
    client_loop: str = "grouped"
    ensemble_shard: str = "none"
    distill_kl: str = "ref"
    kernel_vjp: str = "ref"
    interpret: bool = True
    # federation-scale knobs (DESIGN.md §13); short names again because
    # the grep test bans the scfg spellings outside configs/
    bucketing: str = "off"
    stack_chunk: int = 0
    fedavg: str = "flat"
    fedavg_branch: int = 8
    teacher_chunk: int = 0
    # ((kernel, (vals...)), ...) in KERNEL_BLOCK_ARGS order
    blocks: tuple = ()
    # (((kernel, bucket), (vals...)), ...) from the autotune cache
    tuned: tuple = ()
    # ((kernel, (val_or_None...)), ...) — explicit choices; None inherits
    overrides: tuple = ()

    def replace(self, **kw) -> "ExecPolicy":
        return dataclasses.replace(self, **kw)

    def blocks_for(self, kernel: str, shape=None) -> tuple:
        """Block shapes for one kernel: explicit overrides beat the
        autotuned cache entry for ``shape``'s bucket, which beats the
        registry default table."""
        names = KERNEL_BLOCK_ARGS[kernel]
        vals = dict(self.blocks).get(kernel, _BLOCKS[self.backend][kernel])
        if shape is not None:
            hit = dict(self.tuned).get((kernel, shape_bucket(kernel, shape)))
            if hit is not None:
                vals = hit
        ov = dict(self.overrides).get(kernel)
        if ov is not None:
            vals = tuple(v if o is None else o for v, o in zip(vals, ov))
        if len(vals) != len(names):
            raise ValueError(f"{kernel} expects {len(names)} block values "
                             f"{names}, got {vals!r}")
        return tuple(int(v) for v in vals)

    def block_kwargs(self, kernel: str, shape=None) -> dict:
        return dict(zip(KERNEL_BLOCK_ARGS[kernel],
                        self.blocks_for(kernel, shape)))

    def override_blocks(self, kernel: str, **named) -> "ExecPolicy":
        """New policy with explicit block choices for one kernel; values
        of None inherit (tuned/registry) per position."""
        names = KERNEL_BLOCK_ARGS[kernel]
        bad = set(named) - set(names)
        if bad:
            raise ValueError(f"unknown block args {sorted(bad)} for "
                             f"{kernel} (expected {names})")
        cur = dict(self.overrides)
        prev = cur.get(kernel, (None,) * len(names))
        cur[kernel] = tuple(named.get(n, p) for n, p in zip(names, prev))
        return self.replace(overrides=tuple(sorted(cur.items())))


def _freeze_blocks(table: dict) -> tuple:
    return tuple(sorted((k, tuple(v)) for k, v in table.items()))


def _normalize_overrides(kernel_blocks) -> tuple:
    """Accept scfg.kernel_blocks as a mapping or tuple of pairs, values
    either positional tuples or name->int mappings."""
    if not kernel_blocks:
        return ()
    items = kernel_blocks.items() if hasattr(kernel_blocks, "items") \
        else kernel_blocks
    out = {}
    for kernel, vals in items:
        names = KERNEL_BLOCK_ARGS.get(kernel)
        if names is None:
            raise ValueError(f"unknown kernel {kernel!r} in kernel_blocks "
                             f"(expected one of {tuple(KERNEL_BLOCK_ARGS)})")
        if hasattr(vals, "items"):
            vals = tuple(vals.get(n) for n in names)
        vals = tuple(vals)
        if len(vals) != len(names):
            raise ValueError(f"kernel_blocks[{kernel!r}] expects "
                             f"{len(names)} values {names}, got {vals!r}")
        out[kernel] = tuple(None if v is None else int(v) for v in vals)
    return tuple(sorted(out.items()))


# ---------------------------------------------------- autotune cache IO

def _default_cache_path() -> str:
    return os.environ.get(
        "REPRO_AUTOTUNE_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "repro-dense",
                     "autotune.json"))


def _read_cache_file(path: str) -> dict:
    """{'backend/kernel/bucket': [blocks...]} from one JSON cache file;
    a corrupt or stale-format file degrades to registry defaults with a
    warning instead of failing resolution."""
    if not os.path.exists(path):
        return {}
    try:
        with open(path) as f:
            doc = json.load(f)
        if doc.get("version") != _CACHE_VERSION:
            raise ValueError(f"cache version {doc.get('version')!r} != "
                             f"{_CACHE_VERSION}")
        entries = {}
        for key, ent in doc["entries"].items():
            backend, kernel, bucket = key.split("/")
            names = KERNEL_BLOCK_ARGS[kernel]
            vals = tuple(int(ent["blocks"][n]) for n in names)
            entries[(backend, kernel, bucket)] = vals
        return entries
    except Exception as e:  # noqa: BLE001 — any corruption falls back
        warnings.warn(f"ignoring unreadable autotune cache {path}: {e}; "
                      "falling back to registry default blocks",
                      stacklevel=2)
        return {}


_cache_memo: dict = {}


def _load_cache() -> dict:
    """Seed cache overlaid by the writable cache, memoized per
    (path, mtime) so resolution stays cheap at trace time."""
    path = _default_cache_path()
    sig = (path, _mtime(_SEED_CACHE), _mtime(path))
    if _cache_memo.get("sig") != sig:
        entries = _read_cache_file(_SEED_CACHE)
        entries.update(_read_cache_file(path))
        _cache_memo.clear()
        _cache_memo["sig"] = sig
        _cache_memo["entries"] = entries
    return _cache_memo["entries"]


def _mtime(path):
    try:
        return os.stat(path).st_mtime_ns
    except OSError:
        return None


def clear_caches() -> None:
    """Drop memoized cache state (tests; after external cache edits)."""
    _cache_memo.clear()
    _resolve_memo.clear()


def _write_cache_entry(backend, kernel, bucket, vals, timing_us) -> None:
    path = _default_cache_path()
    doc = {"version": _CACHE_VERSION, "entries": {}}
    if os.path.exists(path):
        try:
            with open(path) as f:
                old = json.load(f)
            if old.get("version") == _CACHE_VERSION:
                doc = old
        except Exception:
            pass  # corrupt writable cache: start fresh
    names = KERNEL_BLOCK_ARGS[kernel]
    doc["entries"][f"{backend}/{kernel}/{bucket}"] = {
        "blocks": dict(zip(names, [int(v) for v in vals])),
        "us": float(timing_us)}
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    os.replace(tmp, path)
    clear_caches()


# -------------------------------------------------------------- buckets

def _pow2_ceil(n: int) -> int:
    p = 1
    while p < max(int(n), 1):
        p *= 2
    return p


def shape_bucket(kernel: str, shape) -> str:
    """Shape-bucket key: kernels with the same next-pow2 problem dims
    share one autotune entry. ``shape`` is the tuple of tuning-relevant
    dims ((rows, vocab) / (Sq, Sk) / (S,))."""
    return "x".join(str(_pow2_ceil(d)) for d in shape)


# ------------------------------------------------------------ resolution

_resolve_memo: dict = {}


def resolve_exec_policy(scfg=None, *, backend=None) -> "ExecPolicy":
    """THE resolution entrypoint: modes and block shapes for one run.

    ``scfg`` may be a DenseExperimentConfig, any knob-carrying namespace,
    an ExecPolicy (returned unchanged — idempotent), or None (pure
    registry defaults for the detected backend). Per-scfg knobs that are
    present and not None override the registry profile; every mode is
    validated here (same error messages the scattered call-site checks
    used to raise). Output is bit-stable for a fixed (backend, scfg,
    cache) triple: resolution is pure in those inputs and memoized when
    scfg hashes.
    """
    if isinstance(scfg, ExecPolicy):
        return scfg
    b = backend or detect_backend(scfg)
    try:
        key = (b, scfg, os.environ.get("REPRO_INTERPRET"),
               _cache_memo.get("sig"))
        hash(key)
    except TypeError:
        key = None
    if key is not None and key in _resolve_memo:
        return _resolve_memo[key]
    prof = _PROFILES[b]

    def knob(name, default):
        v = getattr(scfg, name, None)
        return default if v is None else v

    loop = knob("loop_mode", prof["loop"])
    client_loop = knob("client_loop_mode", prof["client_loop"])
    shard = knob("ensemble_shard_mode", prof["ensemble_shard"])
    kl = knob("distill_kl_mode", prof["distill_kl"])
    vjp = knob("kernel_vjp_mode", prof["kernel_vjp"])
    bucketing = knob("plan_bucketing", prof["bucketing"])
    stack_chunk = knob("stack_chunk", prof["stack_chunk"])
    favg = knob("fedavg_mode", prof["fedavg"])
    fbranch = knob("fedavg_branch", prof["fedavg_branch"])
    tchunk = knob("teacher_chunk", prof["teacher_chunk"])
    check_loop_mode(loop)
    check_client_loop_mode(client_loop)
    check_shard_mode(shard)
    check_kl_mode(kl)
    check_kernel_vjp_mode(vjp)
    check_bucketing_mode(bucketing)
    check_chunk_size("stack_chunk", stack_chunk)
    check_fedavg_mode(favg)
    check_fedavg_branch(fbranch)
    check_chunk_size("teacher_chunk", tchunk)
    interp = prof["interpret"]
    env_i = os.environ.get("REPRO_INTERPRET")
    if env_i is not None and env_i != "":
        interp = env_i not in ("0", "false", "False")
    cache = _load_cache()
    tuned = tuple(sorted((
        ((kernel, bucket), vals)
        for (cb, kernel, bucket), vals in cache.items() if cb == b)))
    pol = ExecPolicy(
        backend=b, loop=loop, client_loop=client_loop, ensemble_shard=shard,
        distill_kl=kl, kernel_vjp=vjp, interpret=bool(interp),
        bucketing=bucketing, stack_chunk=int(stack_chunk), fedavg=favg,
        fedavg_branch=int(fbranch), teacher_chunk=int(tchunk),
        blocks=_freeze_blocks(_BLOCKS[b]), tuned=tuned,
        overrides=_normalize_overrides(getattr(scfg, "kernel_blocks", ())))
    if key is not None:
        _resolve_memo[key] = pol
    return pol


def arch_policy(cfg) -> "ExecPolicy":
    """Model-layer resolution from an ArchConfig: ``kernel_vjp_mode``
    (when set; None → registry), and the config's tile fields
    (attn_block_q/attn_block_kv, ssm_chunk) as explicit block overrides.
    models/attention.py and models/ssm.py route every kernel decision
    through this."""
    pol = resolve_exec_policy(None)
    vjp = getattr(cfg, "kernel_vjp_mode", None)
    if vjp is not None:
        check_kernel_vjp_mode(vjp)
        pol = pol.replace(kernel_vjp=vjp)
    bq = getattr(cfg, "attn_block_q", None)
    bk = getattr(cfg, "attn_block_kv", None)
    if bq is not None or bk is not None:
        pol = pol.override_blocks("flash_attention", block_q=bq, block_k=bk)
    chunk = getattr(cfg, "ssm_chunk", None)
    if chunk is not None:
        pol = pol.override_blocks("ssd_scan", chunk=chunk)
    return pol


# ------------------------------------------------------------- autotuner

def autotune_enabled() -> bool:
    return os.environ.get("REPRO_AUTOTUNE", "") not in ("", "0")


def _timer(fn, reps: int = 3) -> float:
    """Median wall-clock microseconds of ``fn()`` over ``reps`` calls
    (after one warmup). Monkeypatched by the determinism tests."""
    fn()
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append((time.perf_counter() - t0) * 1e6)
    return sorted(ts)[len(ts) // 2]


def _pick_winner(timings) -> int:
    """Index of the fastest candidate; exact ties break to the EARLIEST
    candidate in canonical _CANDIDATES order (deterministic across
    runs and machines with quantized timers)."""
    return min(range(len(timings)), key=lambda i: (timings[i], i))


def _candidate_runner(kernel, shape, blocks, interpret):
    """A thunk timing one kernel at ``shape`` with candidate ``blocks``
    on synthetic inputs (fresh concrete arrays — never the traced
    operands, so tuning composes with jit tracing). Forward entries time
    the pair's forward; ``*_bwd`` entries time the standalone backward
    kernel on precomputed forward residuals, so the two directions tune
    independently (DESIGN.md §13)."""
    import importlib

    import jax
    import jax.numpy as jnp

    # the public names in repro.kernels shadow the submodules (ops.py
    # wrappers are re-exported as repro.kernels.distill_kl etc.), so the
    # low-level modules must be resolved by full dotted path
    if kernel == "distill_kl_bwd":
        _kl = importlib.import_module("repro.kernels.distill_kl")
        rows, v = shape
        t = jnp.linspace(-1.0, 1.0, rows * v, dtype=jnp.float32)
        t = t.reshape(rows, v)
        s = t[:, ::-1]
        # forward residuals at the forward's registry-default blocks —
        # held fixed so only the backward stream is on the clock
        fbr, fbv = _BLOCKS["cpu"]["distill_kl"]
        klv, (mt, zt, _st, ms, zs) = _kl.distill_kl(
            t, s, block_rows=fbr, block_v=fbv, interpret=interpret,
            return_stats=True)
        lse_t, lse_s = mt + jnp.log(zt), ms + jnp.log(zs)
        g = jnp.ones((rows,), jnp.float32)
        br, bv = blocks

        def run():
            jax.block_until_ready(_kl.distill_kl_bwd(
                t, s, lse_t, lse_s, klv, g, block_rows=br, block_v=bv,
                interpret=interpret))
    elif kernel == "flash_attention_bwd":
        _fa = importlib.import_module("repro.kernels.flash_attention")
        sq, sk = shape
        d = 16
        q = jnp.linspace(-1.0, 1.0, sq * d,
                         dtype=jnp.float32).reshape(1, 1, sq, d)
        k = jnp.linspace(-1.0, 1.0, sk * d,
                         dtype=jnp.float32).reshape(1, 1, sk, d)
        fbq, fbk = _BLOCKS["cpu"]["flash_attention"]
        _out, o_f32, lse = _fa.flash_attention(
            q, k, k, causal=True, window=0, block_q=fbq, block_k=fbk,
            interpret=interpret, return_stats=True)
        g = jnp.ones_like(q)
        bq, bk = blocks

        def run():
            jax.block_until_ready(_fa.flash_attention_bwd(
                q, k, k, o_f32, lse, g, causal=True, window=0, scale=None,
                block_q=bq, block_k=bk, interpret=interpret))
    elif kernel == "distill_kl":
        _kl = importlib.import_module("repro.kernels.distill_kl")
        rows, v = shape
        t = jnp.linspace(-1.0, 1.0, rows * v, dtype=jnp.float32)
        t = t.reshape(rows, v)
        s = t[:, ::-1]
        br, bv = blocks

        def run():
            jax.block_until_ready(_kl.distill_kl_vjp(t, s, br, bv,
                                                     interpret, False))
    elif kernel == "flash_attention":
        _fa = importlib.import_module("repro.kernels.flash_attention")
        sq, sk = shape
        d = 16
        q = jnp.linspace(-1.0, 1.0, sq * d,
                         dtype=jnp.float32).reshape(1, 1, sq, d)
        k = jnp.linspace(-1.0, 1.0, sk * d,
                         dtype=jnp.float32).reshape(1, 1, sk, d)
        bq, bk = blocks

        def run():
            jax.block_until_ready(_fa.flash_attention(
                q, k, k, causal=True, window=0, block_q=bq, block_k=bk,
                interpret=interpret))
    elif kernel == "paged_attention":
        _pa = importlib.import_module("repro.kernels.paged_attention")
        # shape = (max_len,): page candidates trade gather granularity
        # against per-block overhead at the engine's sequence capacity
        (t,) = shape
        (page,) = blocks
        r, d = 2, 16
        m = max(1, -(-int(t) // page))
        pool = jnp.linspace(-1.0, 1.0, (r * m + 1) * page * d,
                            dtype=jnp.float32).reshape(r * m + 1, page, 1, d)
        q = jnp.linspace(-1.0, 1.0, r * d,
                         dtype=jnp.float32).reshape(r, 1, d)
        bt = jnp.arange(r * m, dtype=jnp.int32).reshape(r, m) + 1
        seq = jnp.full((r,), int(t), jnp.int32)

        def run():
            jax.block_until_ready(_pa.paged_attention(
                q, pool, pool, bt, seq, interpret=interpret))
    elif kernel == "ssd_scan":
        _ssd = importlib.import_module("repro.kernels.ssd_scan")
        (s,) = shape
        h, p, n = 1, 4, 4
        x = jnp.linspace(-1.0, 1.0, s * h * p,
                         dtype=jnp.float32).reshape(1, s, h, p)
        dt = jnp.full((1, s, h), 0.1, jnp.float32)
        a = -jnp.ones((h,), jnp.float32)
        bmat = jnp.linspace(-1.0, 1.0, s * h * n,
                            dtype=jnp.float32).reshape(1, s, h, n)
        (chunk,) = blocks

        def run():
            jax.block_until_ready(_ssd.ssd_scan(
                x, dt, a, bmat, bmat, chunk=chunk, interpret=interpret))
    else:
        raise ValueError(f"unknown kernel {kernel!r}")
    return run


def autotune_blocks(kernel: str, shape, policy: "ExecPolicy") -> tuple:
    """Block shapes for ``(policy.backend, kernel, bucket(shape))``.

    Cache hit (seed or writable) returns immediately — no timing. On a
    miss with ``REPRO_AUTOTUNE=1`` each candidate (clamped into the
    problem shape and deduplicated, keeping canonical order) is timed
    and the deterministic winner is persisted to the writable cache;
    with autotuning off the registry default is returned untimed.
    """
    bucket = shape_bucket(kernel, shape)
    cached = _load_cache().get((policy.backend, kernel, bucket))
    if cached is not None:
        return cached
    if not autotune_enabled():
        return policy.blocks_for(kernel)
    cands, seen = [], set()
    for cand in _CANDIDATES[kernel]:
        clamped = tuple(min(int(c), _pow2_ceil(d))
                        for c, d in zip(cand, shape))
        if clamped not in seen:
            seen.add(clamped)
            cands.append(clamped)
    timings = [_timer(_candidate_runner(kernel, tuple(int(d) for d in shape),
                                        c, policy.interpret))
               for c in cands]
    win = _pick_winner(timings)
    _write_cache_entry(policy.backend, kernel, bucket, cands[win],
                       timings[win])
    return cands[win]


__all__ = [
    "BACKENDS", "LOOP_MODES", "CLIENT_LOOP_MODES", "SHARD_MODES",
    "KL_MODES", "KERNEL_VJP_MODES", "BUCKETING_MODES", "FEDAVG_MODES",
    "KERNEL_BLOCK_ARGS", "ExecPolicy",
    "detect_backend", "resolve_exec_policy", "arch_policy",
    "shape_bucket", "autotune_blocks", "autotune_enabled", "clear_caches",
    "check_loop_mode", "check_client_loop_mode", "check_shard_mode",
    "check_kl_mode", "check_kernel_vjp_mode", "check_bucketing_mode",
    "check_fedavg_mode", "check_chunk_size", "check_fedavg_branch",
]
