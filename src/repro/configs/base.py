"""Architecture configuration schema + registry.

Every assigned architecture gets one module in ``repro/configs/`` exporting
``CONFIG`` (the exact published shape) and ``smoke()`` (a reduced variant of
the same family: <=2 layers, d_model<=512, <=4 experts) for CPU tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class ArchConfig:
    # identity
    name: str = "unnamed"
    family: str = "dense"           # dense | moe | ssm | hybrid | vlm | audio
    source: str = ""                # citation (paper / model card)

    # transformer trunk
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0               # 0 -> d_model // n_heads
    d_ff: int = 1024
    vocab_size: int = 1024
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    tie_embeddings: bool = True
    max_seq_len: int = 131_072

    # sliding-window pattern (gemma3): window size for local layers and the
    # period of global layers (every `global_every`-th layer is global;
    # 0 -> all layers global/full attention).
    sliding_window: int = 0
    global_every: int = 0

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    first_dense: bool = False       # deepseek: layer 0 uses a dense MLP
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # MLA (deepseek-v2)
    kv_lora_rank: int = 0           # 0 -> standard GQA path
    q_lora_rank: int = 0
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    # SSM (mamba2)
    ssm_state: int = 0              # N; 0 -> no ssm
    ssm_head_dim: int = 64          # P
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv: int = 4
    ssm_n_groups: int = 1

    # hybrid (zamba2): one shared attention block every `attn_every` ssm
    # blocks; n_layers counts ssm blocks + shared-block applications.
    attn_every: int = 0

    # VLM (llama-3.2-vision): a gated cross-attention layer every
    # `cross_every`-th layer; vision frontend is stubbed (precomputed
    # patch embeddings of shape (n_patches, vision_dim)).
    cross_every: int = 0
    n_patches: int = 0
    vision_dim: int = 0

    # audio (musicgen): decoder over EnCodec codes; frontend stubbed
    # (precomputed frame embeddings). vocab_size = codec codebook size.
    audio_frontend: bool = False

    # numerics / execution
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True    # False: python-unrolled stack (the dry-run
                                # uses small unrolled depth variants to get
                                # trip-count-correct HLO cost analysis)
    use_blockwise_attn: bool = True   # flash-style online-softmax attention
                                      # for long sequences (§Perf-1); False
                                      # reproduces the materialized baseline
    attn_block_q: int = 1024          # blockwise attention tile sizes
    attn_block_kv: int = 1024         # (also explicit flash_attention
                                      # block overrides on the exec
                                      # policy — configs/backend.py)
    kernel_vjp_mode: str | None = None  # attention/SSM kernel routing
                                      # (kernels/ops.py, DESIGN.md §9):
                                      # "ref" (pure-XLA model paths,
                                      # autodiff), "autodiff" (bare
                                      # Pallas forward kernels; NOT
                                      # differentiable — the pallas_call
                                      # JVP rule rejects them) or
                                      # "fused" (the custom-VJP Pallas
                                      # kernel pairs: streaming
                                      # backward, the only
                                      # differentiable kernel path).
                                      # None defers to the backend
                                      # registry (configs/backend.py,
                                      # DESIGN.md §11: cpu → "ref",
                                      # gpu/tpu → "fused").

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # ---- derived -----------------------------------------------------
    @property
    def attention_kind(self) -> str:
        return "mla" if self.kv_lora_rank else "gqa"

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS)."""
        d, L = self.d_model, self.n_layers
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family in ("ssm",):
            per_layer = _mamba2_params(self)
            return emb + L * per_layer
        if self.family == "hybrid":
            n_shared_apps = L // (self.attn_every + 1)
            n_ssm = L - n_shared_apps
            shared = _attn_params(self) + 3 * d * self.d_ff  # one shared block
            return emb + n_ssm * _mamba2_params(self) + shared
        attn = _attn_params(self)
        if self.n_experts:
            mlp = (self.n_experts + self.n_shared_experts) * 3 * d * self.d_ff_expert \
                + d * self.n_experts
            if self.first_dense:
                dense_mlp = 3 * d * (self.d_ff_expert * (self.top_k + self.n_shared_experts))
                return emb + attn * L + mlp * (L - 1) + dense_mlp
        else:
            mlp = 3 * d * self.d_ff
        total = emb + L * (attn + mlp)
        if self.cross_every:
            n_cross = L // (self.cross_every + 1)
            total += n_cross * _attn_params(self)
        return total

    def active_param_count(self) -> int:
        """Per-token active params (MoE: only routed top-k + shared)."""
        if not self.n_experts:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        emb = self.vocab_size * d
        attn = _attn_params(self)
        mlp_active = (self.top_k + self.n_shared_experts) * 3 * d * self.d_ff_expert \
            + d * self.n_experts
        return emb + L * (attn + mlp_active)


def _attn_params(cfg: ArchConfig) -> int:
    d = cfg.d_model
    if cfg.kv_lora_rank:
        qd = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
        q = (d * cfg.q_lora_rank + cfg.q_lora_rank * cfg.n_heads * qd) \
            if cfg.q_lora_rank else d * cfg.n_heads * qd
        kv = d * (cfg.kv_lora_rank + cfg.qk_rope_head_dim) \
            + cfg.kv_lora_rank * cfg.n_heads * (cfg.qk_nope_head_dim + cfg.v_head_dim)
        o = cfg.n_heads * cfg.v_head_dim * d
        return q + kv + o
    hd = cfg.head_dim
    return d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd + cfg.n_heads * hd * d


def _mamba2_params(cfg: ArchConfig) -> int:
    d, di, g, n = cfg.d_model, cfg.d_inner, cfg.ssm_n_groups, cfg.ssm_state
    h = cfg.n_ssm_heads
    in_proj = d * (2 * di + 2 * g * n + h)
    conv = cfg.ssm_conv * (di + 2 * g * n)
    out = di * d
    return in_proj + conv + out + 2 * h + di  # A, D, norm


# ----------------------------------------------------------------- registry

_REGISTRY: dict[str, str] = {}


def register(name: str, module: str) -> None:
    _REGISTRY[name] = module


def available_archs() -> list[str]:
    _ensure_registered()
    return sorted(_REGISTRY)


_ASSIGNED = [
    "gemma3_4b", "musicgen_large", "deepseek_v2_236b", "deepseek_v2_lite_16b",
    "qwen1_5_4b", "phi3_medium_14b", "llama3_2_3b", "llama3_2_vision_11b",
    "mamba2_130m", "zamba2_7b",
]


def _ensure_registered() -> None:
    if _REGISTRY:
        return
    for mod in _ASSIGNED:
        _REGISTRY[mod.replace("_", "-")] = f"repro.configs.{mod}"


_ALIASES = {
    "qwen1.5-4b": "qwen1-5-4b",
    "llama3.2-3b": "llama3-2-3b",
    "llama-3.2-vision-11b": "llama3-2-vision-11b",
    "llama3.2-vision-11b": "llama3-2-vision-11b",
}


def _resolve(name: str) -> str:
    key = name.replace("_", "-")
    key = _ALIASES.get(key, key)
    if key not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {available_archs()}")
    return key


def _module(name: str):
    _ensure_registered()
    import importlib
    return importlib.import_module(_REGISTRY[_resolve(name)])


def get_config(name: str) -> ArchConfig:
    """Look up an architecture by id, e.g. ``gemma3-4b`` or ``qwen1.5-4b``."""
    return _module(name).CONFIG


def get_smoke_config(name: str) -> ArchConfig:
    """Reduced same-family variant for CPU smoke tests."""
    return _module(name).smoke()
