"""mamba2-130m [ssm] — SSD (state-space duality), attention-free.

Source: [arXiv:2405.21060]: 24L d_model=768 vocab=50280 ssm_state=128,
head_dim=64, expand=2 (d_inner=1536, 24 ssm heads).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-130m", family="ssm", source="arXiv:2405.21060",
    n_layers=24, d_model=768, n_heads=0, n_kv_heads=0, head_dim=1,
    d_ff=0, vocab_size=50280, ssm_state=128, ssm_head_dim=64,
    ssm_expand=2, ssm_chunk=256, ssm_conv=4, ssm_n_groups=1,
    max_seq_len=1_048_576,
)


def smoke() -> ArchConfig:
    return CONFIG.replace(
        n_layers=2, d_model=128, vocab_size=512, ssm_state=16,
        ssm_head_dim=32, ssm_chunk=32,
        dtype="float32", param_dtype="float32", remat=False)
