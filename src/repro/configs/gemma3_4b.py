"""gemma3-4b [dense] — 5:1 local:global sliding-window attention, 128k ctx.

Source: [hf:google/gemma-3-1b-pt] scaled per assignment:
34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-4b", family="dense", source="hf:google/gemma-3-1b-pt",
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4, head_dim=256,
    d_ff=10240, vocab_size=262144, rope_theta=1_000_000.0,
    sliding_window=1024, global_every=6, max_seq_len=131_072,
)


def smoke() -> ArchConfig:
    return CONFIG.replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=512, sliding_window=8, global_every=2,
        dtype="float32", param_dtype="float32", remat=False)
