"""deepseek-v2-236b [moe] — MLA (kv_lora=512) + 2 shared + 160 routed top-6.

Source: [arXiv:2405.04434]: 60L d_model=5120 128H d_ff_expert=1536
vocab=102400, q_lora=1536, first layer dense MLP.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b", family="moe", source="arXiv:2405.04434",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128, head_dim=128,
    d_ff=12288, vocab_size=102400,
    n_experts=160, n_shared_experts=2, top_k=6, d_ff_expert=1536,
    first_dense=True, kv_lora_rank=512, q_lora_rank=1536,
    qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
    max_seq_len=131_072,
)


def smoke() -> ArchConfig:
    return CONFIG.replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=192, vocab_size=512, n_experts=4, n_shared_experts=1, top_k=2,
        d_ff_expert=64, kv_lora_rank=32, q_lora_rank=48,
        qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
        dtype="float32", param_dtype="float32", remat=False)
