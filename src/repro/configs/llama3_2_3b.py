"""llama3.2-3b [dense] — small llama3. Source: [hf:meta-llama/Llama-3.2-1B]
scaled: 28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3-2-3b", family="dense", source="hf:meta-llama/Llama-3.2-1B",
    n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=128256, rope_theta=500_000.0, max_seq_len=131_072,
)


def smoke() -> ArchConfig:
    return CONFIG.replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=512, dtype="float32", param_dtype="float32",
        remat=False)
