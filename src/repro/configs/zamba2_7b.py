"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention block.

Source: [arXiv:2411.15242]: 81L d_model=3584 32H (kv=32) d_ff=14336
vocab=32000 ssm_state=64. One *shared* (weight-tied) attention+MLP block is
applied after every 6 mamba blocks (13 applications + 3 tail mamba blocks).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b", family="hybrid", source="arXiv:2411.15242",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, head_dim=112,
    d_ff=14336, vocab_size=32000, ssm_state=64, ssm_head_dim=64,
    ssm_expand=2, ssm_chunk=256, ssm_conv=4, ssm_n_groups=1,
    attn_every=6, max_seq_len=1_048_576,
)


def smoke() -> ArchConfig:
    return CONFIG.replace(
        n_layers=5, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=256, vocab_size=512, ssm_state=16, ssm_head_dim=32,
        ssm_chunk=32, attn_every=2,
        dtype="float32", param_dtype="float32", remat=False)
