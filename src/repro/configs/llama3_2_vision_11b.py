"""llama-3.2-vision-11b [vlm] — gated cross-attn image layers.

Source: [hf:meta-llama/Llama-3.2-11B-Vision]: 40L d_model=4096 32H (kv=8)
d_ff=14336 vocab=128256; 8 cross-attn layers (1 per 4 self layers).
Vision frontend (ViT) is a stub — input_specs provides projected patch
embeddings (n_patches=1601, vision_dim=4096).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3-2-vision-11b", family="vlm",
    source="hf:meta-llama/Llama-3.2-11B-Vision",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=128256, rope_theta=500_000.0,
    cross_every=4, n_patches=1601, vision_dim=4096, max_seq_len=131_072,
)


def smoke() -> ArchConfig:
    return CONFIG.replace(
        n_layers=6, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=512, cross_every=2, n_patches=17,
        vision_dim=64, dtype="float32", param_dtype="float32", remat=False)
