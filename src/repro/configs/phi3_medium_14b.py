"""phi3-medium-14b [dense] — RoPE SwiGLU GQA. Source: [arXiv:2404.14219]:
40L d_model=5120 40H (GQA kv=10) d_ff=17920 vocab=100352."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi3-medium-14b", family="dense", source="arXiv:2404.14219",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=10, d_ff=17920,
    vocab_size=100352, max_seq_len=131_072,
)


def smoke() -> ArchConfig:
    return CONFIG.replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab_size=512, dtype="float32", param_dtype="float32", remat=False)
