"""musicgen-large [audio] — decoder-only LM over EnCodec tokens.

Source: [arXiv:2306.05284]: 48L d_model=2048 32H (kv=32) d_ff=8192
vocab=2048 (EnCodec codebook). The mel/conv codec frontend is a stub —
the decoder consumes discrete codec tokens (input_specs provides them).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large", family="audio", source="arXiv:2306.05284",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab_size=2048, max_seq_len=32_768,
)


def smoke() -> ArchConfig:
    return CONFIG.replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
        vocab_size=128, dtype="float32", param_dtype="float32", remat=False)
