"""qwen1.5-4b [dense] — QKV bias. Source: [hf:Qwen/Qwen1.5-0.5B] scaled:
40L d_model=2560 20H (kv=20) d_ff=6912 vocab=151936."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1-5-4b", family="dense", source="hf:Qwen/Qwen1.5-0.5B",
    n_layers=40, d_model=2560, n_heads=20, n_kv_heads=20, d_ff=6912,
    vocab_size=151936, qkv_bias=True, max_seq_len=32_768,
)


def smoke() -> ArchConfig:
    return CONFIG.replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
        vocab_size=512, dtype="float32", param_dtype="float32", remat=False)
