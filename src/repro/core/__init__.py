"""DENSE core: the paper's primary contribution.

Two-stage data-free one-shot FL (Algorithm 1): generator training against
the client-model ensemble (losses.py, generator.py, ensemble.py) followed
by ensemble->student distillation (dense.py). The LLM-scale distributed
instantiation lives in repro/core/dense_llm.py (launched via
repro/launch/).
"""
from repro.core.dense import (train_dense_server, make_dense_steps,
                              evaluate, merge_bn_stats, DenseHistory)
from repro.core.ensemble import (Client, ensemble_logits, split_clients,
                                 group_clients, stack_grouped,
                                 grouped_ensemble_logits,
                                 stack_homogeneous, ensemble_logits_stacked)
from repro.core.losses import (softmax_kl, ce_loss, bn_loss, div_loss,
                               gen_loss, distill_loss)
from repro.core.generator import (img_generator, img_generator_init,
                                  tok_generator, tok_generator_init)

__all__ = [
    "train_dense_server", "make_dense_steps", "evaluate", "merge_bn_stats",
    "DenseHistory", "Client", "ensemble_logits", "split_clients",
    "group_clients", "stack_grouped", "grouped_ensemble_logits",
    "stack_homogeneous", "ensemble_logits_stacked", "softmax_kl", "ce_loss",
    "bn_loss", "div_loss", "gen_loss", "distill_loss", "img_generator",
    "img_generator_init", "tok_generator", "tok_generator_init",
]
