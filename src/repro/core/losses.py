"""The DENSE loss functions (paper §2.2–2.3).

  L_CE  (Eq. 2)  similarity      — CE(D(x̂), y) on ensemble-average logits
  L_BN  (Eq. 3)  stability       — match client BN batch stats to running
  L_div (Eq. 4)  transferability — maximize teacher/student KL only where
                                   their argmax predictions disagree
  L_gen (Eq. 5)  = L_CE + λ1 L_BN + λ2 L_div
  L_dis (Eq. 6)  distillation    — KL(D(x̂) ‖ f_S(x̂))
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def softmax_kl(p_logits: jnp.ndarray, q_logits: jnp.ndarray,
               temperature: float = 1.0) -> jnp.ndarray:
    """Per-sample KL( softmax(p/T) ‖ softmax(q/T) ), shape (B,)."""
    pl = p_logits.astype(jnp.float32) / temperature
    ql = q_logits.astype(jnp.float32) / temperature
    logp = jax.nn.log_softmax(pl, axis=-1)
    logq = jax.nn.log_softmax(ql, axis=-1)
    return jnp.sum(jnp.exp(logp) * (logp - logq), axis=-1)


def ce_loss(avg_logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Eq. (2)."""
    logp = jax.nn.log_softmax(avg_logits.astype(jnp.float32), axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], -1))


def bn_loss(per_client_stats) -> jnp.ndarray:
    """Eq. (3): (1/m) Σ_k Σ_l ‖μ_l(x̂) − μ_{k,l}‖ + ‖σ²_l(x̂) − σ²_{k,l}‖."""
    total = jnp.zeros((), jnp.float32)
    for stats in per_client_stats:            # one list per client
        for s in stats:                       # one dict per BN layer
            total = total + jnp.linalg.norm(s["mean"] - s["running_mean"]) \
                + jnp.linalg.norm(s["var"] - s["running_var"])
    return total / max(len(per_client_stats), 1)


def div_loss(avg_logits: jnp.ndarray, student_logits: jnp.ndarray,
             temperature: float = 1.0) -> jnp.ndarray:
    """Eq. (4): −ω·KL(D‖f_S); ω = 1[argmax D ≠ argmax f_S].

    Returned value is the loss to *minimize* (already negated); gradients
    flow to the generator through both logit tensors.
    """
    omega = (jnp.argmax(avg_logits, -1)
             != jnp.argmax(student_logits, -1)).astype(jnp.float32)
    kl = softmax_kl(avg_logits, student_logits, temperature)
    return -jnp.mean(omega * kl)


def gen_loss(avg_logits, labels, per_client_stats, student_logits, *,
             lambda_bn: float, lambda_div: float):
    """Eq. (5). Returns (total, dict of parts)."""
    l_ce = ce_loss(avg_logits, labels)
    l_bn = bn_loss(per_client_stats)
    l_div = div_loss(avg_logits, student_logits)
    total = l_ce + lambda_bn * l_bn + lambda_div * l_div
    return total, {"ce": l_ce, "bn": l_bn, "div": l_div}


def distill_loss(avg_logits: jnp.ndarray, student_logits: jnp.ndarray,
                 temperature: float = 1.0) -> jnp.ndarray:
    """Eq. (6): mean_b KL(D(x̂) ‖ f_S(x̂))."""
    return jnp.mean(softmax_kl(avg_logits, student_logits, temperature))
