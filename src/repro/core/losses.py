"""The DENSE loss functions (paper §2.2–2.3).

  L_CE  (Eq. 2)  similarity      — CE(D(x̂), y) on ensemble-average logits
  L_BN  (Eq. 3)  stability       — match client BN batch stats to running
  L_div (Eq. 4)  transferability — maximize teacher/student KL only where
                                   their argmax predictions disagree
  L_gen (Eq. 5)  = L_CE + λ1 L_BN + λ2 L_div
  L_dis (Eq. 6)  distillation    — KL(D(x̂) ‖ f_S(x̂))

Every KL-based loss takes ``mode``: ``"ref"`` (materialized jnp
log-softmax, differentiated by autodiff) or ``"fused"`` (the Pallas
custom-VJP kernel pair, kernels/distill_kl — streams vocab blocks in
BOTH directions, never materializing an (R, V) softmax; DESIGN.md §9).
The per-run choice and the kernel's block shapes come from the backend
execution-policy registry (``configs.backend.resolve_exec_policy``,
DESIGN.md §11); callers pass ``mode=policy.distill_kl`` and optionally
the policy itself. ``with_teacher_grad=False`` lets
stop-gradient'd-teacher call sites (stage 2's student step) skip the
fused dL/dt stream.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import backend as _B

KL_MODES = _B.KL_MODES

# re-export: step builders still validate through losses.check_mode
check_mode = _B.check_kl_mode


def softmax_kl(p_logits: jnp.ndarray, q_logits: jnp.ndarray,
               temperature: float = 1.0, *, mode: str = "ref",
               block_rows: int | None = None, block_v: int | None = None,
               with_teacher_grad: bool = True, policy=None) -> jnp.ndarray:
    """Per-sample KL( softmax(p/T) ‖ softmax(q/T) ), shape (B,).

    Temperature scaling stays OUTSIDE the fused kernel: the 1/T chain
    rule flows through the scaling op, so both modes share it. Like the
    ref path, any leading batch shape is accepted (the kernel sees the
    flattened (rows, V) view). Explicit ``block_rows``/``block_v``
    override the policy's (registry/autotuned) choice."""
    check_mode(mode)
    pt = p_logits.astype(jnp.float32) / temperature
    qt = q_logits.astype(jnp.float32) / temperature
    if mode == "fused":
        from repro.kernels import ops as kops
        pol = _B.resolve_exec_policy(policy)
        if block_rows is not None or block_v is not None:
            pol = pol.override_blocks("distill_kl", block_rows=block_rows,
                                      block_v=block_v)
        lead, v = pt.shape[:-1], pt.shape[-1]
        kl = kops.distill_kl(pt.reshape(-1, v), qt.reshape(-1, v),
                             with_teacher_grad=with_teacher_grad,
                             policy=pol)
        return kl.reshape(lead)
    logp = jax.nn.log_softmax(pt, axis=-1)
    logq = jax.nn.log_softmax(qt, axis=-1)
    return jnp.sum(jnp.exp(logp) * (logp - logq), axis=-1)


def ce_loss(avg_logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Eq. (2)."""
    logp = jax.nn.log_softmax(avg_logits.astype(jnp.float32), axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], -1))


def bn_loss(per_client_stats) -> jnp.ndarray:
    """Eq. (3): (1/m) Σ_k Σ_l ‖μ_l(x̂) − μ_{k,l}‖ + ‖σ²_l(x̂) − σ²_{k,l}‖."""
    total = jnp.zeros((), jnp.float32)
    for stats in per_client_stats:            # one list per client
        for s in stats:                       # one dict per BN layer
            total = total + jnp.linalg.norm(s["mean"] - s["running_mean"]) \
                + jnp.linalg.norm(s["var"] - s["running_var"])
    return total / max(len(per_client_stats), 1)


def div_loss(avg_logits: jnp.ndarray, student_logits: jnp.ndarray,
             temperature: float = 1.0, *, mode: str = "ref",
             policy=None) -> jnp.ndarray:
    """Eq. (4): −ω·KL(D‖f_S); ω = 1[argmax D ≠ argmax f_S].

    Returned value is the loss to *minimize* (already negated); gradients
    flow to the generator through both logit tensors — the fused mode
    keeps the dL/dt (teacher-side) stream on for exactly this reuse.
    """
    omega = (jnp.argmax(avg_logits, -1)
             != jnp.argmax(student_logits, -1)).astype(jnp.float32)
    kl = softmax_kl(avg_logits, student_logits, temperature, mode=mode,
                    policy=policy)
    return -jnp.mean(omega * kl)


def gen_loss(avg_logits, labels, per_client_stats, student_logits, *,
             lambda_bn: float, lambda_div: float, mode: str = "ref",
             policy=None):
    """Eq. (5). Returns (total, dict of parts)."""
    l_ce = ce_loss(avg_logits, labels)
    l_bn = bn_loss(per_client_stats)
    l_div = div_loss(avg_logits, student_logits, mode=mode, policy=policy)
    total = l_ce + lambda_bn * l_bn + lambda_div * l_div
    return total, {"ce": l_ce, "bn": l_bn, "div": l_div}


def distill_loss(avg_logits: jnp.ndarray, student_logits: jnp.ndarray,
                 temperature: float = 1.0, *, mode: str = "ref",
                 with_teacher_grad: bool = True, policy=None) -> jnp.ndarray:
    """Eq. (6): mean_b KL(D(x̂) ‖ f_S(x̂)).

    Student steps pass ``with_teacher_grad=False`` (the teacher is
    stop-gradient'd upstream) so the fused backward skips its dL/dt
    stream; the default stays gradient-complete for any other caller."""
    return jnp.mean(softmax_kl(avg_logits, student_logits, temperature,
                               mode=mode, with_teacher_grad=with_teacher_grad,
                               policy=policy))
