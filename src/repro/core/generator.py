"""Generators for the DENSE data-generation stage.

``img_generator_*`` — DCGAN-style conv generator (DAFL [2] architecture, as
used by the paper, §3.1.4): fc → BN → 2×(upsample, conv, BN, lrelu) → conv
→ tanh. Generator BN layers always use batch statistics (no running stats).

``tok_generator_*`` — the LM-family analogue (DESIGN.md §7.4): a light
transformer that maps (z, y) to a sequence of *soft embeddings* consumed by
decoder-LM clients via ``forward(..., embeds=...)``.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import layers as L


# ------------------------------------------------------------- image path --

def _gbn_init(c):
    return {"scale": jnp.ones((c,), jnp.float32),
            "bias": jnp.zeros((c,), jnp.float32)}


def _gbn(p, x, eps=1e-5):
    axes = tuple(range(x.ndim - 1))
    mu = jnp.mean(x, axes)
    var = jnp.var(x, axes)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return y * p["scale"] + p["bias"]


def img_generator_init(key, *, nz: int = 100, img_size: int = 32,
                       out_ch: int = 3, base: int = 64) -> dict:
    s0 = img_size // 4
    ks = jax.random.split(key, 4)
    return {
        "fc": L.linear_init(ks[0], nz, 2 * base * s0 * s0, bias=True),
        "bn0": _gbn_init(2 * base),
        "c1": L.conv_init(ks[1], 2 * base, 2 * base, 3),
        "bn1": _gbn_init(2 * base),
        "c2": L.conv_init(ks[2], 2 * base, base, 3),
        "bn2": _gbn_init(base),
        "c3": L.conv_init(ks[3], base, out_ch, 3),
    }


def img_generator(p: dict, z: jnp.ndarray, *, img_size: int,
                  base: int = 64) -> jnp.ndarray:
    """z: (B, nz) -> images (B, H, W, C) in (-1, 1)."""
    B = z.shape[0]
    s0 = img_size // 4
    x = L.linear(p["fc"], z).reshape(B, s0, s0, 2 * base)
    x = _gbn(p["bn0"], x)
    x = jax.image.resize(x, (B, 2 * s0, 2 * s0, 2 * base), "nearest")
    x = jax.nn.leaky_relu(_gbn(p["bn1"], L.conv2d(p["c1"], x)), 0.2)
    x = jax.image.resize(x, (B, img_size, img_size, 2 * base), "nearest")
    x = jax.nn.leaky_relu(_gbn(p["bn2"], L.conv2d(p["c2"], x)), 0.2)
    return jnp.tanh(L.conv2d(p["c3"], x))


# ---------------------------------------------------------------- LM path --

def tok_generator_init(key, *, nz: int = 64, seq: int = 64, d_model: int,
                       d_g: int = 256, n_blocks: int = 2,
                       n_classes: int = 0) -> dict:
    """n_classes > 0 adds a label-conditioning table (class-conditional
    synthesis, mirroring the paper's random one-hot y)."""
    ks = jax.random.split(key, 3 + 2 * n_blocks)
    p = {
        "pos": (jax.random.normal(ks[0], (seq, d_g)) * 0.02).astype(jnp.float32),
        "z_proj": L.linear_init(ks[1], nz, d_g, bias=True),
        "out": L.linear_init(ks[2], d_g, d_model, bias=True),
        "blocks": [],
    }
    if n_classes:
        p["label"] = L.embed_init(ks[-1], n_classes, d_g)
    for i in range(n_blocks):
        k1, k2 = ks[3 + 2 * i], ks[4 + 2 * i]
        p["blocks"].append({
            "norm1": L.layernorm_init(d_g),
            "mix": L.linear_init(k1, seq, seq, bias=True),   # token mixer
            "norm2": L.layernorm_init(d_g),
            "mlp": L.gelu_mlp_init(k2, d_g, 4 * d_g),
        })
    return p


def tok_generator(p: dict, z: jnp.ndarray,
                  labels: jnp.ndarray | None = None) -> jnp.ndarray:
    """z: (B, nz) -> soft embeddings (B, S, d_model)."""
    h = L.linear(p["z_proj"], z)[:, None, :] + p["pos"][None]
    if labels is not None and "label" in p:
        h = h + L.embed(p["label"], labels)[:, None, :]
    for blk in p["blocks"]:
        y = L.layernorm(blk["norm1"], h)
        y = jnp.swapaxes(L.linear(blk["mix"], jnp.swapaxes(y, 1, 2)), 1, 2)
        h = h + y
        h = h + L.gelu_mlp(blk["mlp"], L.layernorm(blk["norm2"], h))
    return L.linear(p["out"], h)
