"""Heterogeneous client-model ensembles (Eq. 1: average logits).

The paper's key aggregation move: average *logits*, never parameters —
which is what makes heterogeneous client architectures possible. Clients
are (CNNSpec, params) pairs; the python loop over clients unrolls under
jit (m is small server-side), and for homogeneous ensembles a vmapped
fast path stacks the client params.

On the production mesh the same average is realized as a psum over the
ensemble mesh axis — see repro/launch/dense_llm.py.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.models.cnn import CNNSpec, cnn_apply


@dataclass
class Client:
    spec: CNNSpec
    params: dict
    n_data: int = 0                 # |D_k| (FedAvg weighting; DENSE ignores)
    class_counts: jnp.ndarray | None = None


def ensemble_logits(specs: Sequence[CNNSpec], params_list, x: jnp.ndarray,
                    *, with_bn_stats: bool = False):
    """Eq. (1): D(x) = (1/m) sum_k f^k(x). Eval-mode (running BN stats).

    specs are static (shape info); params_list is a traced pytree so jitted
    callers don't bake client weights in as constants. with_bn_stats
    additionally returns each client's per-BN-layer batch statistics of x —
    the inputs to L_BN (Eq. 3).
    """
    logits_sum = None
    all_stats = []
    for spec, params in zip(specs, params_list):
        lg, _, stats = cnn_apply(params, spec, x, train=False)
        lg = lg.astype(jnp.float32)
        logits_sum = lg if logits_sum is None else logits_sum + lg
        if with_bn_stats:
            all_stats.append(stats)
    avg = logits_sum / len(specs)
    if with_bn_stats:
        return avg, all_stats
    return avg


def split_clients(clients: Sequence[Client]):
    """-> (static spec tuple, traced params list)."""
    return tuple(c.spec for c in clients), [c.params for c in clients]


def stack_homogeneous(clients: Sequence[Client]):
    """Stack same-architecture client params for a vmapped ensemble."""
    specs = {c.spec for c in clients}
    assert len(specs) == 1, "stack_homogeneous requires identical specs"
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs),
                           *[c.params for c in clients])
    return clients[0].spec, stacked


def ensemble_logits_stacked(spec: CNNSpec, stacked: dict, x: jnp.ndarray):
    """Vmapped homogeneous ensemble — one batched forward instead of m."""
    def one(p):
        return cnn_apply(p, spec, x, train=False)[0].astype(jnp.float32)
    return jnp.mean(jax.vmap(one)(stacked), axis=0)
