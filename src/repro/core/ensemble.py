"""Heterogeneous client-model ensembles (Eq. 1: average logits).

The paper's key aggregation move: average *logits*, never parameters —
which is what makes heterogeneous client architectures possible. Clients
are (CNNSpec, params) pairs.

Two evaluation paths:

  * ``ensemble_logits`` — reference implementation: a python loop over
    clients that unrolls under jit. Compile size and runtime scale O(m).
  * ``grouped_ensemble_logits`` — the fast path: clients are grouped by
    ``CNNSpec`` (``group_clients``), each group's params are stacked once
    at setup (``stack_grouped``) and the whole group is evaluated with a
    single ``jax.vmap`` forward — a 20-client homogeneous federation
    compiles/executes 1 batched forward instead of 20. Singleton groups
    fall back to a direct (un-vmapped) forward. The ``with_bn_stats``
    path needed by L_BN (Eq. 3) is supported: per-client stats are
    unstacked from the vmapped forward so ``losses.bn_loss`` is unchanged.

Grouping reorders clients by first occurrence of their spec; both the
logit average and L_BN are order-invariant sums over clients, so the two
paths agree to float tolerance (tests/test_fastpath.py).

With a ("clients", "data") mesh (``grouped_ensemble_logits(..., mesh=)``,
routed by ``scfg.ensemble_shard_mode`` — see fl/sharding.py) each stacked
group's leading client dim is sharded over the ``clients`` axis and the
group sum lowers to per-shard partial sums + one ``psum`` via
``shard_map`` — the host realization of the pod-axis all-reduce in
repro/core/dense_llm.py (DESIGN.md §8). Groups whose size the axis does
not divide keep the single-device vmap path, so the mesh is always
correctness-safe.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

import numpy as np

from repro.models.cnn import (CNNSpec, cnn_apply, cnn_stack_apply_grouped,
                              is_groupable)


@dataclass
class Client:
    spec: CNNSpec
    params: dict
    n_data: int = 0                 # |D_k| (FedAvg weighting; DENSE ignores)
    class_counts: jnp.ndarray | None = None


def ensemble_logits(specs: Sequence[CNNSpec], params_list, x: jnp.ndarray,
                    *, with_bn_stats: bool = False):
    """Eq. (1): D(x) = (1/m) sum_k f^k(x). Eval-mode (running BN stats).

    Reference (unrolled) path. specs are static (shape info); params_list
    is a traced pytree so jitted callers don't bake client weights in as
    constants. with_bn_stats additionally returns each client's
    per-BN-layer batch statistics of x — the inputs to L_BN (Eq. 3).
    """
    logits_sum = None
    all_stats = []
    for spec, params in zip(specs, params_list):
        lg, _, stats = cnn_apply(params, spec, x, train=False)
        lg = lg.astype(jnp.float32)
        logits_sum = lg if logits_sum is None else logits_sum + lg
        if with_bn_stats:
            all_stats.append(stats)
    avg = logits_sum / len(specs)
    if with_bn_stats:
        return avg, all_stats
    return avg


def split_clients(clients: Sequence[Client]):
    """-> (static spec tuple, traced params list)."""
    return tuple(c.spec for c in clients), [c.params for c in clients]


def group_clients(clients: Sequence[Client]):
    """Group clients by architecture with a deterministic key order.

    -> list of (spec, client_indices) pairs, ordered by the *first
    occurrence* of each spec (insertion order — never a set, whose
    iteration order is unstable across processes).
    """
    groups: dict[CNNSpec, list[int]] = {}
    for i, c in enumerate(clients):
        groups.setdefault(c.spec, []).append(i)
    return [(spec, tuple(idx)) for spec, idx in groups.items()]


def _stack_chunked(trees, chunk: int | None = None):
    """Stack a list of per-client pytrees on a new leading axis.

    ``chunk > 0`` builds the stack in fixed-size slices concatenated on
    device (DESIGN.md §13): the host-side transfer buffer peaks at
    O(chunk) client trees instead of one O(m) staging blob, which is
    what lets a m=1000 federation stack without an m-sized host spike.
    Values are bitwise identical either way (stack/concatenate move
    bytes, they don't compute).
    """
    if chunk and 0 < chunk < len(trees):
        parts = [jax.tree.map(lambda *xs: jnp.stack(xs),
                              *trees[i:i + chunk])
                 for i in range(0, len(trees), chunk)]
        return jax.tree.map(lambda *ps: jnp.concatenate(ps, 0), *parts)
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def stack_grouped(clients: Sequence[Client], *, apply_masks: bool = True,
                  chunk: int | None = None):
    """Build the grouped-ensemble representation.

    -> (gspecs, gparams) where gspecs is the *static* part — a tuple of
    (CNNSpec, group_size) — and gparams the *traced* part: one params
    pytree per group, stacked along a leading client axis for groups of
    size > 1 and kept flat for singletons (which skip vmap entirely).
    Stack once at setup; jitted steps then take gparams as an argument so
    client weights are not baked in as constants.

    A federation built by the grouped client-training engine
    (fl/federation.ClientList) already IS this representation — its
    prebuilt (gspecs, gparams) is returned as-is, so params trained on
    the stacked client axis flow into the ensemble without an
    unstack/restack round trip through host memory.

    A federation that went through upload admission
    (fl.protocol.admit_uploads) carries ``group_masks``: with
    ``apply_masks=True`` (default) quarantined clients are statically
    sliced out here (``apply_group_masks``), so EVERY grouped consumer —
    the DENSE teacher, the baselines, the sharded psum path — sees
    exactly the representation a federation built without those clients
    would produce. ``apply_masks=False`` returns the raw full-width
    stack (quarantined slots zero-filled).
    """
    masks = getattr(clients, "group_masks", None) if apply_masks else None
    pre = getattr(clients, "grouped", None)
    if pre is not None:
        gspecs, gparams = pre
    else:
        gspecs, gparams = [], []
        for spec, idx in group_clients(clients):
            gspecs.append((spec, len(idx)))
            if len(idx) == 1:
                gparams.append(clients[idx[0]].params)
            else:
                # chunk > 0 stages the stack in O(chunk) host slices
                # (DESIGN.md §13); bitwise the same values either way
                gparams.append(_stack_chunked(
                    [clients[i].params for i in idx], chunk))
    if masks is not None and any(m is not None for m in masks):
        return apply_group_masks(gspecs, gparams, masks)
    return tuple(gspecs), gparams


def apply_group_masks(gspecs, gparams, group_masks):
    """Statically slice the survivors out of a grouped representation.

    ``group_masks`` is per-group: None (whole group survives) or a host
    numpy bool array over the group's client axis. Because the masks are
    static (admission decisions are made on host, before tracing), the
    surviving rows are gathered with constant indices and fully-
    quarantined groups disappear from the unrolled group loop — the
    result is the *same pytree values and the same downstream program* as
    a federation built without the quarantined clients, which is what
    makes quarantine bit-identical to removal (tests/test_faults.py).

    -> (gspecs, gparams) with surviving sizes; a group reduced to one
    client becomes a flat singleton (matching ``stack_grouped`` of the
    reduced federation).
    """
    if group_masks is None or all(m is None for m in group_masks):
        return tuple(gspecs), list(gparams)
    if len(group_masks) != len(gspecs):
        raise ValueError(f"group_masks has {len(group_masks)} entries for "
                         f"{len(gspecs)} groups")
    new_specs, new_params = [], []
    for (spec, size), params, gm in zip(gspecs, gparams, group_masks):
        if gm is None:
            new_specs.append((spec, size))
            new_params.append(params)
            continue
        gm = np.asarray(gm, bool)
        if gm.shape != (size,):
            raise ValueError(f"group mask shape {gm.shape} != ({size},)")
        idx = np.nonzero(gm)[0]
        if idx.size == 0:
            continue                     # fully quarantined: static skip
        if idx.size == size:
            new_specs.append((spec, size))
            new_params.append(params)
        elif idx.size == 1:
            new_specs.append((spec, 1))
            new_params.append(jax.tree.map(
                lambda a, _i=int(idx[0]): a[_i], params))
        else:
            new_specs.append((spec, int(idx.size)))
            new_params.append(jax.tree.map(lambda a: a[idx], params))
    if not new_specs:
        raise ValueError("every client is quarantined: empty ensemble")
    return tuple(new_specs), new_params


def _group_stack_forward(params, spec, x, size, with_stats):
    """(logits (size, B, K) f32, stacked stats) for one stacked group —
    fused grouped-channel forward for groupable kinds (the conv-stack
    zoo AND the ResNet/WRN kinds — models/cnn.py), vmap fallback for
    anything else."""
    if is_groupable(spec.kind):
        # fully-fused grouped-channel forward (models/cnn.py)
        lgs, stacked_stats = cnn_stack_apply_grouped(
            params, spec, x, size, with_stats=with_stats)
        return lgs.astype(jnp.float32), stacked_stats

    def one(p, _spec=spec):
        lg_k, _, st_k = cnn_apply(p, _spec, x, train=False)
        return lg_k.astype(jnp.float32), st_k

    return jax.vmap(one)(params)


def _chunked_stack_sum(params, spec, x, size, chunk, with_stats,
                       reduce=None):
    """Stream one stacked group's logit sum in ``chunk``-client slices
    (DESIGN.md §13): ``lax.scan`` over sub-stacks of the leading client
    axis, each chunk's (chunk, B, K) logits folded into an fp32 (B, K)
    accumulator — the teacher never materializes the full (size, B, K)
    activation block, and the scan body is rematerialized
    (``jax.checkpoint``) so differentiation (the generator's teacher
    gradient) re-runs chunks instead of keeping per-chunk residuals
    alive. ``reduce`` (e.g. a ``psum`` under shard_map) is applied to
    every chunk's partial sum, the remainder chunk included.

    Per-client BN stats are still returned with the full (size, ...)
    leading dim — they are (size, C)-small; the memory win is the
    activations, not the stats.
    """
    r = reduce if reduce is not None else (lambda s: s)
    nc, rem = divmod(size, chunk)
    acc = jnp.zeros((x.shape[0], spec.num_classes), jnp.float32)
    stats = None
    if nc:
        main = jax.tree.map(
            lambda a: a[:nc * chunk].reshape((nc, chunk) + a.shape[1:]),
            params)

        @jax.checkpoint
        def fwd(p_c):
            return _group_stack_forward(p_c, spec, x, chunk, with_stats)

        def body(carry, p_c):
            lgs, st = fwd(p_c)
            return carry + r(jnp.sum(lgs, axis=0)), st

        acc, st_main = jax.lax.scan(body, acc, main)
        stats = jax.tree.map(
            lambda a: a.reshape((nc * chunk,) + a.shape[2:]), st_main)
    if rem:
        tail = jax.tree.map(lambda a: a[nc * chunk:], params)
        lgs_t, st_t = _group_stack_forward(tail, spec, x, rem, with_stats)
        acc = acc + r(jnp.sum(lgs_t, axis=0))
        stats = st_t if stats is None else jax.tree.map(
            lambda a, b: jnp.concatenate([a, b], axis=0), stats, st_t)
    return acc, stats


def _group_sum_sharded(params, spec, x, size, mesh, with_stats,
                       chunk=None):
    """Sharded group sum: the leading client dim splits over the mesh's
    ``clients`` axis, each shard runs the same fused/vmapped forward on
    its size // axis clients, and the sum lowers to ONE ``psum`` — or,
    with ``chunk`` set, to one psum per scanned sub-chunk
    (``_chunked_stack_sum``), keeping the replicated fp32 accumulator
    exact while no shard ever materializes its full local logit block.

    Returns (group_sum (B, K) f32 replicated, stacked stats with the full
    (size, ...) leading dim sharded over ``clients``). Callers guarantee
    divisibility (fl.sharding.group_shardable).
    """
    from jax.experimental.shard_map import shard_map

    from repro.fl.sharding import CLIENT_AXIS, client_axis_size

    loc = size // client_axis_size(mesh)

    def local(p_shard, xb):
        if chunk and 0 < chunk < loc:
            s, st = _chunked_stack_sum(
                p_shard, spec, xb, loc, chunk, with_stats,
                reduce=lambda v: jax.lax.psum(v, CLIENT_AXIS))
        else:
            lgs, st = _group_stack_forward(p_shard, spec, xb, loc,
                                           with_stats)
            s = jax.lax.psum(jnp.sum(lgs, axis=0), CLIENT_AXIS)
        return (s, st) if with_stats else s

    out_specs = (P(), P(CLIENT_AXIS)) if with_stats else P()
    out = shard_map(local, mesh=mesh, in_specs=(P(CLIENT_AXIS), P()),
                    out_specs=out_specs, check_rep=False)(params, x)
    return out if with_stats else (out, [])


def grouped_ensemble_logits(gspecs, gparams, x: jnp.ndarray, *,
                            with_bn_stats: bool = False, mesh=None,
                            group_masks=None, chunk: int | None = None):
    """Eq. (1) over the grouped representation — one vmapped forward per
    architecture group instead of one unrolled forward per client.

    Matches ``ensemble_logits`` up to float tolerance; with_bn_stats
    returns a flat per-client stats list (group order) compatible with
    ``losses.bn_loss``, which is order-invariant.

    mesh: optional ("clients", "data") mesh (fl/sharding.py). Stacked
    groups whose size the ``clients`` axis divides evaluate as one
    shard_map whose group sum is a single psum over that axis; other
    groups (and singletons) keep the single-device path.

    group_masks: optional per-group survivor masks (fl.protocol
    admission). Statically sliced out up front (``apply_group_masks``),
    so the average runs over survivors only — divisor included — and the
    sharded path sees the surviving group size (re-checking
    divisibility, falling back to the single-device forward when the
    reduced size no longer shards).

    chunk: > 0 streams each stacked group's logit sum through
    ``chunk``-client scanned slices (``_chunked_stack_sum``, DESIGN.md
    §13) so the stage-2 teacher never materializes a (size, B, K)
    activation block; routed from ``scfg.teacher_chunk``
    (configs.backend.resolve_exec_policy). Sum order within a group is
    unchanged — partial fp32 sums accumulate in client order — so the
    result matches the unchunked path to float tolerance (and bitwise
    when the chunk divides the group evenly on one device).
    """
    if group_masks is not None:
        gspecs, gparams = apply_group_masks(gspecs, gparams, group_masks)
    if mesh is not None:
        from repro.fl.sharding import group_shardable
    m = sum(size for _, size in gspecs)
    logits_sum = None
    all_stats = []
    for (spec, size), params in zip(gspecs, gparams):
        if size == 1:
            lg, _, stats = cnn_apply(params, spec, x, train=False)
            group_sum = lg.astype(jnp.float32)
            if with_bn_stats:
                all_stats.append(stats)
        else:
            if mesh is not None and group_shardable(mesh, size):
                group_sum, stacked_stats = _group_sum_sharded(
                    params, spec, x, size, mesh, with_bn_stats,
                    chunk=chunk)
            elif chunk and 0 < chunk < size:
                group_sum, stacked_stats = _chunked_stack_sum(
                    params, spec, x, size, chunk, with_bn_stats)
            else:
                lgs, stacked_stats = _group_stack_forward(
                    params, spec, x, size, with_bn_stats)
                group_sum = jnp.sum(lgs, axis=0)
            if with_bn_stats:
                for k in range(size):
                    all_stats.append(jax.tree.map(lambda a, _k=k: a[_k],
                                                  stacked_stats))
        logits_sum = group_sum if logits_sum is None \
            else logits_sum + group_sum
    avg = logits_sum / m
    if with_bn_stats:
        return avg, all_stats
    return avg


def stack_homogeneous(clients: Sequence[Client]):
    """Stack same-architecture client params for a vmapped ensemble."""
    groups = group_clients(clients)
    assert len(groups) == 1, "stack_homogeneous requires identical specs"
    spec, idx = groups[0]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs),
                           *[clients[i].params for i in idx])
    return spec, stacked


def ensemble_logits_stacked(spec: CNNSpec, stacked: dict, x: jnp.ndarray):
    """Vmapped homogeneous ensemble — one batched forward instead of m."""
    def one(p):
        return cnn_apply(p, spec, x, train=False)[0].astype(jnp.float32)
    return jnp.mean(jax.vmap(one)(stacked), axis=0)
