"""DENSE two-stage server training (Algorithm 1).

Stage 1 (data generation): T_G generator steps per epoch minimizing
L_gen = L_CE + λ1 L_BN + λ2 L_div against the frozen client ensemble and
the *current* student (whose decision boundary defines L_div).

Stage 2 (model distillation): a student step on the same synthetic batch
minimizing KL(D(x̂) ‖ f_S(x̂)).

Faithful to Algorithm 1 by default (one noise batch per epoch, one student
step). ``s_steps > 1`` / ``replay=True`` are beyond-paper extensions kept
off unless asked for (EXPERIMENTS.md reports them separately).

Fast-path design
----------------
The frozen ensemble is held in the grouped-vmap representation
(ensemble.stack_grouped): clients are grouped by CNNSpec at
``make_dense_steps`` setup and each group is evaluated with a single
vmapped forward, so the per-step ensemble cost is O(#architectures), not
O(#clients).

The epoch driver is selected by the resolved execution policy
(``configs.backend.resolve_exec_policy``; ``scfg.loop_mode`` when set,
else the backend registry default — cpu: "python", gpu/tpu: "fused"):

  * ``"python"`` — per-step jit, one host sync (``float``) per
    metric per epoch. Fastest on single-core CPU hosts where the fused
    scan compiles slowly.
  * ``"fused"``  — device-resident: ``scfg.loop_chunk`` epochs are chunked
    into ONE ``jax.lax.scan`` program with donated carry buffers
    (gen/student params + optimizer states never round-trip to host) and
    on-device metric stacking, so the host syncs once per chunk instead
    of 3× per epoch. The win grows with accelerator dispatch latency.

Both modes derive per-epoch PRNG keys identically
(``jax.random.split(key, epochs)`` then kz/ky/ks per epoch), so they
produce the same student up to compilation-order float noise
(tests/test_fastpath.py asserts agreement).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.backend import resolve_exec_policy
from repro.core import generator as G
from repro.core import losses as LS
from repro.core.ensemble import (Client, grouped_ensemble_logits,
                                 stack_grouped)
from repro.models.cnn import CNNSpec, cnn_apply, cnn_logits, cnn_init
from repro import optim


def merge_bn_stats(opt_params, stat_params):
    """Overwrite BN running stats (functional aux output) after an
    optimizer step — they carry no gradient and must not be SGD-updated."""
    def f(path, a, b):
        last = path[-1]
        key = getattr(last, "key", None)
        return b if key in ("mean", "var") else a
    return jax.tree_util.tree_map_with_path(f, opt_params, stat_params)


@dataclass
class DenseHistory:
    gen_loss: list = field(default_factory=list)
    gen_parts: list = field(default_factory=list)
    dis_loss: list = field(default_factory=list)
    acc: list = field(default_factory=list)


def make_dense_steps(clients: Sequence[Client], student_spec: CNNSpec,
                     scfg, *, use_bn: bool = True, use_div: bool = True,
                     mesh=None):
    """Build jitted steps closed over the frozen (grouped) ensemble.

    Returns (gen_step, student_step, g_opt, s_opt, gparams, epoch_step,
    epochs_step): gparams is the grouped-stacked client params
    (ensemble.stack_grouped) that every step takes as its traced ensemble
    argument; epochs_step scans epoch_step over a chunk of per-epoch keys
    with donated carries (the loop_mode="fused" driver).

    mesh defaults to ``fl.sharding.resolve_mesh(scfg)``
    (scfg.ensemble_shard_mode): with a ("clients", "data") mesh the
    stacked client params are placed client-sharded and the teacher's
    logit mean lowers to one psum over the ``clients`` axis
    (ensemble._group_sum_sharded).

    use_bn / use_div=False reproduce the paper's ablations (Table 6).
    """
    # ALL execution modes resolve through the backend registry
    # (configs.backend.resolve_exec_policy, DESIGN.md §11): scfg knobs
    # when set, per-backend defaults otherwise. The stage-2 KL
    # implementation ("ref" jnp autodiff vs "fused" Pallas custom-VJP
    # kernel pair — kernels/distill_kl, DESIGN.md §9) routes both the
    # student's L_dis and the generator's L_div, so the fused dL/dt
    # stream is reused in stage 1.
    pol = resolve_exec_policy(scfg)
    if mesh is None:
        from repro.fl.sharding import resolve_mesh
        mesh = resolve_mesh(pol)
    kl_mode = pol.distill_kl
    # nan_policy="skip" compiles an isfinite guard into BOTH steps: a
    # non-finite loss (or grad) step becomes a no-op update via
    # jnp.where over the param/opt-state trees. Any other policy
    # compiles the guard out entirely — the healthy path is unchanged.
    nan_guard = getattr(scfg, "nan_policy", "raise") == "skip"
    g_opt = optim.adam(scfg.g_lr)
    s_opt = optim.sgd(scfg.s_lr, momentum=scfg.s_momentum)
    img = scfg.image_size
    # stack_grouped statically slices quarantined clients out when the
    # federation carries admission masks (fl.protocol.admit_uploads):
    # the teacher is built from survivors only, bit-identically to a
    # federation without the quarantined clients. stack_chunk stages the
    # stack through O(chunk) host slices; teacher_chunk streams the
    # stage-1/2 ensemble sum through scanned client slices so the
    # teacher never materializes (m, B, C) activations (DESIGN.md §13).
    t_chunk = pol.teacher_chunk
    gspecs, gparams = stack_grouped(clients, chunk=pol.stack_chunk)
    if mesh is not None:
        from repro.fl.sharding import put_grouped
        gparams = put_grouped(gspecs, gparams, mesh)

    def gen_forward(gen_p, z):
        return G.img_generator(gen_p, z, img_size=img)

    @jax.jit
    def gen_step(gen_p, g_state, stu_p, gparams, z, y):
        def loss_fn(gp):
            x = gen_forward(gp, z)
            avg, stats = grouped_ensemble_logits(gspecs, gparams, x,
                                                 with_bn_stats=True,
                                                 mesh=mesh, chunk=t_chunk)
            stu = cnn_logits(stu_p, student_spec, x)
            l_ce = LS.ce_loss(avg, y)
            l_bn = LS.bn_loss(stats) if use_bn else jnp.zeros(())
            l_div = LS.div_loss(avg, stu, mode=kl_mode, policy=pol) \
                if use_div else jnp.zeros(())
            total = l_ce + scfg.lambda_bn * l_bn + scfg.lambda_div * l_div
            return total, {"ce": l_ce, "bn": l_bn, "div": l_div}

        (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(gen_p)
        new_p, new_state = g_opt.update(grads, g_state, gen_p)
        if nan_guard:
            ok = jnp.isfinite(loss) & jnp.isfinite(optim.global_norm(grads))
            new_p = jax.tree.map(lambda n, o: jnp.where(ok, n, o),
                                 new_p, gen_p)
            new_state = jax.tree.map(lambda n, o: jnp.where(ok, n, o),
                                     new_state, g_state)
        return new_p, new_state, loss, parts

    @jax.jit
    def student_step(stu_p, s_state, gen_p, gparams, z):
        x = jax.lax.stop_gradient(gen_forward(gen_p, z))
        avg = grouped_ensemble_logits(gspecs, gparams, x, mesh=mesh,
                                      chunk=t_chunk)

        def loss_fn(sp):
            logits, new_sp, _ = cnn_apply(sp, student_spec, x, train=True)
            # avg is stop-gradient'd upstream: skip the fused dL/dt stream
            return LS.distill_loss(avg, logits, mode=kl_mode,
                                   with_teacher_grad=False,
                                   policy=pol), new_sp

        (loss, stats_p), grads = jax.value_and_grad(loss_fn, has_aux=True)(stu_p)
        new_p, new_state = s_opt.update(grads, s_state, stu_p)
        new_p = merge_bn_stats(new_p, stats_p)
        if nan_guard:
            # guards the merged BN stats too: a non-finite synthetic
            # batch would otherwise poison the running mean/var
            ok = jnp.isfinite(loss) & jnp.isfinite(optim.global_norm(grads))
            new_p = jax.tree.map(lambda n, o: jnp.where(ok, n, o),
                                 new_p, stu_p)
            new_state = jax.tree.map(lambda n, o: jnp.where(ok, n, o),
                                     new_state, s_state)
        return new_p, new_state, loss

    t_g = scfg.t_g
    s_steps = getattr(scfg, "s_steps", 1)
    nz, b, ncls = scfg.nz, scfg.synth_batch, scfg.num_classes

    def _epoch_body(gen_p, g_state, stu_p, s_state, gparams, key):
        """One Algorithm-1 epoch: T_G generator steps (lines 8-11) then
        the distillation step(s) (lines 13-14). Pure-jax; shared by the
        jitted epoch_step and the fused multi-epoch scan. The python
        driver mirrors this key derivation exactly."""
        kz, ky, ks = jax.random.split(key, 3)
        z = jax.random.normal(kz, (b, nz))
        y = jax.random.randint(ky, (b,), 0, ncls)

        def gbody(carry, _):
            gp, gs = carry
            gp, gs, loss, parts = gen_step(gp, gs, stu_p, gparams, z, y)
            return (gp, gs), (loss, parts)

        (gen_p, g_state), (gl, parts) = jax.lax.scan(
            gbody, (gen_p, g_state), None, length=t_g)

        # first student step reuses the epoch's z (Algorithm 1); extra
        # steps (s_steps > 1, beyond-paper) draw fresh noise
        extra = jax.random.normal(ks, (max(s_steps - 1, 0), b, nz))
        zs = jnp.concatenate([z[None], extra], axis=0)

        def sbody(carry, z_i):
            sp, ss = carry
            sp, ss, loss = student_step(sp, ss, gen_p, gparams, z_i)
            return (sp, ss), loss

        (stu_p, s_state), dl = jax.lax.scan(sbody, (stu_p, s_state), zs)
        metrics = {"gen_loss": gl[-1],
                   "parts": jax.tree.map(lambda a: a[-1], parts),
                   "dis_loss": dl[-1]}
        return gen_p, g_state, stu_p, s_state, metrics

    epoch_step = jax.jit(_epoch_body)

    @functools.partial(jax.jit, donate_argnums=(0, 1, 2, 3))
    def epochs_step(gen_p, g_state, stu_p, s_state, gparams, keys):
        """loop_mode="fused": a chunk of len(keys) epochs as ONE device
        program. Carries are donated (params/opt states stay resident);
        per-epoch metrics are stacked on device and fetched by the caller
        in a single host sync per chunk."""
        def body(carry, key):
            gp, gs, sp, ss = carry
            gp, gs, sp, ss, m = _epoch_body(gp, gs, sp, ss, gparams, key)
            return (gp, gs, sp, ss), m

        (gen_p, g_state, stu_p, s_state), metrics = jax.lax.scan(
            body, (gen_p, g_state, stu_p, s_state), keys)
        return gen_p, g_state, stu_p, s_state, metrics

    return (gen_step, student_step, g_opt, s_opt, gparams, epoch_step,
            epochs_step)


def _chunk_bounds(epochs: int, chunk: int, eval_every: int,
                  ckpt_every: int = 0, start: int = 0):
    """Chunk [start, epochs) into scan programs of <= chunk epochs, never
    crossing an eval or checkpoint boundary (0 disables either kind).
    ``start`` > 0 resumes mid-schedule (checkpoint restore): the bounds
    after a checkpoint boundary are identical whether the run started at
    0 or resumed at that boundary, which is what makes fused-mode resume
    replay the same chunk programs."""
    bounds, e = [], start
    while e < epochs:
        nxt = min(e + chunk, epochs)
        if eval_every:
            nxt = min(nxt, ((e // eval_every) + 1) * eval_every)
        if ckpt_every:
            nxt = min(nxt, ((e // ckpt_every) + 1) * ckpt_every)
        bounds.append((e, nxt))
        e = nxt
    return bounds


def train_dense_server(key, clients: Sequence[Client], scfg,
                       student_spec: CNNSpec | None = None, *,
                       eval_fn: Callable | None = None,
                       use_bn: bool = True, use_div: bool = True,
                       eval_every: int = 0,
                       student_params: dict | None = None,
                       _poison_epochs=(), _stop_after_epoch: int = 0):
    """Run Algorithm 1. Returns (student_params, gen_params, history).

    Execution modes resolve through the backend registry
    (configs.backend.resolve_exec_policy, DESIGN.md §11): scfg knobs
    when set, per-backend defaults otherwise.
    loop_mode selects the epoch driver ("python" per-step jit or
    "fused" device-resident chunks of scfg.loop_chunk epochs; see
    module docstring).
    scfg.ensemble_shard_mode="clients" additionally shards the frozen
    client stack over a ("clients", "data") mesh (fl/sharding.py) — a
    pure placement/lowering choice, same math (DESIGN.md §8).
    scfg.distill_kl_mode selects the stage-2 KL implementation ("ref"
    jnp autodiff or "fused" Pallas custom-VJP pair, DESIGN.md §9) —
    also a pure implementation choice, same math.

    Self-healing (DESIGN.md §10). ``scfg.nan_policy`` decides what a
    non-finite generator/student loss means:

      * ``"raise"`` (default) — FloatingPointError at the first bad
        epoch (host-side check of the fetched metrics).
      * ``"skip"`` — the bad *step* is a compiled no-op (isfinite guard
        inside the jitted steps, make_dense_steps); training continues.
      * ``"rollback"`` — restore the last good host snapshot: epoch
        granularity under the python driver, chunk granularity under the
        fused driver (the whole bad chunk's epochs are dropped; carries
        are copied before the donated scan).

    Checkpoint/resume. With ``scfg.checkpoint_every`` > 0 and
    ``scfg.checkpoint_path`` set, the FULL server state (gen/student
    params, both optimizer states, the base epoch-key and the epoch
    index) is written through checkpoint/io.py every N epochs, and an
    existing checkpoint at that path is restored on entry. Both drivers
    re-derive ``epoch_keys`` from the restored base key, so a killed run
    resumes bit-identically (tests/test_checkpoint.py); history covers
    only post-resume epochs.

    ``_poison_epochs`` / ``_stop_after_epoch`` are test-only fault hooks:
    NaN-fill the listed epochs' latent batch (python driver), and return
    early after N epochs to simulate a mid-run kill.
    """
    from repro.checkpoint import (checkpoint_exists, restore_checkpoint,
                                  save_checkpoint)

    student_spec = student_spec or CNNSpec(
        kind=scfg.global_kind, num_classes=scfg.num_classes,
        in_ch=scfg.in_ch, width=scfg.width, image_size=scfg.image_size)
    nan_policy = getattr(scfg, "nan_policy", "raise")
    if nan_policy not in ("raise", "skip", "rollback"):
        raise ValueError(f"unknown nan_policy {nan_policy!r} "
                         "(expected 'raise', 'skip' or 'rollback')")
    k_gen, k_stu, key = jax.random.split(key, 3)
    gen_p = G.img_generator_init(k_gen, nz=scfg.nz, img_size=scfg.image_size,
                                 out_ch=scfg.in_ch)
    stu_p = student_params if student_params is not None \
        else cnn_init(k_stu, student_spec)

    (gen_step, student_step, g_opt, s_opt, gparams, epoch_step,
     epochs_step) = make_dense_steps(clients, student_spec, scfg,
                                     use_bn=use_bn, use_div=use_div)
    g_state = g_opt.init(gen_p)
    s_state = s_opt.init(stu_p)

    ck_every = int(getattr(scfg, "checkpoint_every", 0) or 0)
    ck_path = getattr(scfg, "checkpoint_path", "") or ""
    ckpt_on = bool(ck_every and ck_path)
    start_epoch = 0
    if ckpt_on and checkpoint_exists(ck_path):
        like = {"gen_p": gen_p, "g_state": g_state, "stu_p": stu_p,
                "s_state": s_state, "key": key,
                "epoch": np.zeros((), np.int64)}
        st = restore_checkpoint(ck_path, like)
        gen_p, g_state = st["gen_p"], st["g_state"]
        stu_p, s_state = st["stu_p"], st["s_state"]
        key, start_epoch = st["key"], int(st["epoch"])

    def save_ckpt(gp, gs, sp, ss, epoch_done):
        save_checkpoint(ck_path,
                        {"gen_p": gp, "g_state": gs, "stu_p": sp,
                         "s_state": ss, "key": key,
                         "epoch": np.asarray(epoch_done, np.int64)},
                        meta={"epoch": int(epoch_done),
                              "epochs": int(scfg.epochs)})

    hist = DenseHistory()
    s_steps = getattr(scfg, "s_steps", 1)
    loop_mode = resolve_exec_policy(scfg).loop
    loop_chunk = max(1, int(getattr(scfg, "loop_chunk", 8)))
    poison = frozenset(_poison_epochs or ())
    # both drivers consume the SAME per-epoch key stream so they are
    # interchangeable (and testable against each other); the stream
    # depends only on the (restored) base key, never on start_epoch
    epoch_keys = jax.random.split(key, scfg.epochs)

    def maybe_eval(epoch_done):
        if eval_fn is not None and eval_every and \
                epoch_done % eval_every == 0:
            hist.acc.append((epoch_done, eval_fn(stu_p, student_spec)))

    def check_finite(gl, dl, where):
        bad = not (np.all(np.isfinite(gl)) and np.all(np.isfinite(dl)))
        if bad and nan_policy == "raise":
            raise FloatingPointError(
                f"non-finite loss at {where} (gen={gl}, dis={dl}); "
                "set scfg.nan_policy='skip' or 'rollback' to self-heal")
        return bad

    if loop_mode == "fused":
        snap = None
        for lo, hi in _chunk_bounds(scfg.epochs, loop_chunk, eval_every,
                                    ck_every if ckpt_on else 0,
                                    start_epoch):
            if nan_policy == "rollback":
                # epochs_step donates its carries — snapshot copies
                snap = jax.tree.map(jnp.copy,
                                    (gen_p, g_state, stu_p, s_state))
            gen_p, g_state, stu_p, s_state, metrics = epochs_step(
                gen_p, g_state, stu_p, s_state, gparams, epoch_keys[lo:hi])
            m = jax.device_get(metrics)      # ONE host sync per chunk
            hist.gen_loss.extend(float(v) for v in m["gen_loss"])
            hist.dis_loss.extend(float(v) for v in m["dis_loss"])
            hist.gen_parts.extend(
                {k: float(v[i]) for k, v in m["parts"].items()}
                for i in range(hi - lo))
            bad = check_finite(m["gen_loss"], m["dis_loss"],
                               f"epochs [{lo}, {hi})")
            if bad and nan_policy == "rollback":
                gen_p, g_state, stu_p, s_state = snap
            maybe_eval(hi)
            if _stop_after_epoch and hi >= _stop_after_epoch:
                return stu_p, gen_p, hist    # simulated kill beats save
            if ckpt_on and hi % ck_every == 0:
                save_ckpt(gen_p, g_state, stu_p, s_state, hi)
    elif loop_mode == "python":
        b, nz = scfg.synth_batch, scfg.nz
        snap = (gen_p, g_state, stu_p, s_state)
        for epoch in range(start_epoch, scfg.epochs):
            # identical derivation to _epoch_body
            kz, ky, ks = jax.random.split(epoch_keys[epoch], 3)
            z = jax.random.normal(kz, (b, nz))
            if epoch in poison:
                z = jnp.full_like(z, jnp.nan)
            y = jax.random.randint(ky, (b,), 0, scfg.num_classes)
            for _ in range(scfg.t_g):
                gen_p, g_state, gl, parts = gen_step(gen_p, g_state, stu_p,
                                                     gparams, z, y)
            stu_p, s_state, dl = student_step(stu_p, s_state, gen_p,
                                              gparams, z)
            if s_steps > 1:
                extra = jax.random.normal(ks, (s_steps - 1, b, nz))
                for j in range(s_steps - 1):
                    stu_p, s_state, dl = student_step(stu_p, s_state, gen_p,
                                                      gparams, extra[j])
            hist.gen_loss.append(float(gl))
            hist.gen_parts.append({k: float(v) for k, v in parts.items()})
            hist.dis_loss.append(float(dl))
            bad = check_finite(hist.gen_loss[-1], hist.dis_loss[-1],
                               f"epoch {epoch}")
            if nan_policy == "rollback":
                if bad:
                    gen_p, g_state, stu_p, s_state = snap
                else:
                    snap = (gen_p, g_state, stu_p, s_state)
            maybe_eval(epoch + 1)
            if _stop_after_epoch and epoch + 1 >= _stop_after_epoch:
                return stu_p, gen_p, hist    # simulated kill beats save
            if ckpt_on and (epoch + 1) % ck_every == 0:
                save_ckpt(gen_p, g_state, stu_p, s_state, epoch + 1)
    else:
        raise ValueError(f"unknown loop_mode {loop_mode!r} "
                         "(expected 'python' or 'fused')")
    return stu_p, gen_p, hist


@functools.partial(jax.jit, static_argnames=("spec",))
def _eval_correct(params, spec: CNNSpec, xb, yb, mask):
    """Scan over pre-batched (nb, B, ...) eval data; returns the total
    correct count as a device scalar (no per-batch host sync)."""
    def body(tot, inp):
        xi, yi, mi = inp
        logits = cnn_logits(params, spec, xi)
        hit = (jnp.argmax(logits, -1) == yi) & mi
        return tot + jnp.sum(hit.astype(jnp.int32)), None

    tot, _ = jax.lax.scan(body, jnp.zeros((), jnp.int32), (xb, yb, mask))
    return tot


def evaluate(params, spec: CNNSpec, x: np.ndarray, y: np.ndarray,
             batch: int = 512, device_batches: int = 64) -> float:
    """Top-1 accuracy, eval-mode BN.

    Batches are padded to a rectangle and reduced with a jit-scanned
    program per device chunk of `device_batches` batches; per-chunk
    correct counts stay on device and the host syncs ONCE at the end —
    versus one sync per batch before. Chunking keeps device memory
    bounded at batch*device_batches rows for arbitrarily large eval
    sets."""
    x, y = np.asarray(x), np.asarray(y)
    n = len(y)
    batch = max(1, min(batch, n))
    nb = -(-n // batch)
    pad = nb * batch - n
    if pad:
        x = np.concatenate([x, np.zeros((pad, *x.shape[1:]), x.dtype)])
        y = np.concatenate([y, np.zeros((pad,), y.dtype)])
    mask = (np.arange(nb * batch) < n).reshape(nb, batch)
    xb = x.reshape(nb, batch, *x.shape[1:])
    yb = y.reshape(nb, batch)
    totals = []
    for i in range(0, nb, device_batches):
        totals.append(_eval_correct(params, spec,
                                    jnp.asarray(xb[i:i + device_batches]),
                                    jnp.asarray(yb[i:i + device_batches]),
                                    jnp.asarray(mask[i:i + device_batches])))
    return int(sum(totals)) / n
