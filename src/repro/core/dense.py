"""DENSE two-stage server training (Algorithm 1).

Stage 1 (data generation): T_G generator steps per epoch minimizing
L_gen = L_CE + λ1 L_BN + λ2 L_div against the frozen client ensemble and
the *current* student (whose decision boundary defines L_div).

Stage 2 (model distillation): a student step on the same synthetic batch
minimizing KL(D(x̂) ‖ f_S(x̂)).

Faithful to Algorithm 1 by default (one noise batch per epoch, one student
step). ``s_steps > 1`` / ``replay=True`` are beyond-paper extensions kept
off unless asked for (EXPERIMENTS.md reports them separately).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import generator as G
from repro.core import losses as LS
from repro.core.ensemble import Client, ensemble_logits, split_clients
from repro.models.cnn import CNNSpec, cnn_apply, cnn_logits, cnn_init
from repro import optim


def merge_bn_stats(opt_params, stat_params):
    """Overwrite BN running stats (functional aux output) after an
    optimizer step — they carry no gradient and must not be SGD-updated."""
    def f(path, a, b):
        last = path[-1]
        key = getattr(last, "key", None)
        return b if key in ("mean", "var") else a
    return jax.tree_util.tree_map_with_path(f, opt_params, stat_params)


@dataclass
class DenseHistory:
    gen_loss: list = field(default_factory=list)
    gen_parts: list = field(default_factory=list)
    dis_loss: list = field(default_factory=list)
    acc: list = field(default_factory=list)


def make_dense_steps(clients: Sequence[Client], student_spec: CNNSpec,
                     scfg, *, use_bn: bool = True, use_div: bool = True):
    """Build jitted (gen_step, student_step) closed over the frozen ensemble.

    use_bn / use_div=False reproduce the paper's ablations (Table 6).
    """
    g_opt = optim.adam(scfg.g_lr)
    s_opt = optim.sgd(scfg.s_lr, momentum=scfg.s_momentum)
    img = scfg.image_size
    specs, cparams = split_clients(clients)

    def gen_forward(gen_p, z):
        return G.img_generator(gen_p, z, img_size=img)

    @jax.jit
    def gen_step(gen_p, g_state, stu_p, cparams, z, y):
        def loss_fn(gp):
            x = gen_forward(gp, z)
            avg, stats = ensemble_logits(specs, cparams, x,
                                         with_bn_stats=True)
            stu = cnn_logits(stu_p, student_spec, x)
            l_ce = LS.ce_loss(avg, y)
            l_bn = LS.bn_loss(stats) if use_bn else jnp.zeros(())
            l_div = LS.div_loss(avg, stu) if use_div else jnp.zeros(())
            total = l_ce + scfg.lambda_bn * l_bn + scfg.lambda_div * l_div
            return total, {"ce": l_ce, "bn": l_bn, "div": l_div}

        (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(gen_p)
        new_p, new_state = g_opt.update(grads, g_state, gen_p)
        return new_p, new_state, loss, parts

    @jax.jit
    def student_step(stu_p, s_state, gen_p, cparams, z):
        x = jax.lax.stop_gradient(gen_forward(gen_p, z))
        avg = ensemble_logits(specs, cparams, x)

        def loss_fn(sp):
            logits, new_sp, _ = cnn_apply(sp, student_spec, x, train=True)
            return LS.distill_loss(avg, logits), new_sp

        (loss, stats_p), grads = jax.value_and_grad(loss_fn, has_aux=True)(stu_p)
        new_p, new_state = s_opt.update(grads, s_state, stu_p)
        new_p = merge_bn_stats(new_p, stats_p)
        return new_p, new_state, loss

    t_g = scfg.t_g
    s_steps = getattr(scfg, "s_steps", 1)
    nz, b, ncls = scfg.nz, scfg.synth_batch, scfg.num_classes

    @jax.jit
    def epoch_step(gen_p, g_state, stu_p, s_state, cparams, key):
        """One Algorithm-1 epoch as a single device program: T_G generator
        steps (lines 8-11) then the distillation step(s) (lines 13-14)."""
        kz, ky, ks = jax.random.split(key, 3)
        z = jax.random.normal(kz, (b, nz))
        y = jax.random.randint(ky, (b,), 0, ncls)

        def gbody(carry, _):
            gp, gs = carry
            gp, gs, loss, parts = gen_step(gp, gs, stu_p, cparams, z, y)
            return (gp, gs), (loss, parts)

        (gen_p, g_state), (gl, parts) = jax.lax.scan(
            gbody, (gen_p, g_state), None, length=t_g)

        # first student step reuses the epoch's z (Algorithm 1); extra
        # steps (s_steps > 1, beyond-paper) draw fresh noise
        extra = jax.random.normal(ks, (max(s_steps - 1, 0), b, nz))
        zs = jnp.concatenate([z[None], extra], axis=0)

        def sbody(carry, z_i):
            sp, ss = carry
            sp, ss, loss = student_step(sp, ss, gen_p, cparams, z_i)
            return (sp, ss), loss

        (stu_p, s_state), dl = jax.lax.scan(sbody, (stu_p, s_state), zs)
        metrics = {"gen_loss": gl[-1],
                   "parts": jax.tree.map(lambda a: a[-1], parts),
                   "dis_loss": dl[-1]}
        return gen_p, g_state, stu_p, s_state, metrics

    return gen_step, student_step, g_opt, s_opt, cparams, epoch_step


def train_dense_server(key, clients: Sequence[Client], scfg,
                       student_spec: CNNSpec | None = None, *,
                       eval_fn: Callable | None = None,
                       use_bn: bool = True, use_div: bool = True,
                       eval_every: int = 0,
                       student_params: dict | None = None):
    """Run Algorithm 1. Returns (student_params, gen_params, history)."""
    student_spec = student_spec or CNNSpec(
        kind=scfg.global_kind, num_classes=scfg.num_classes,
        in_ch=scfg.in_ch, width=scfg.width, image_size=scfg.image_size)
    k_gen, k_stu, key = jax.random.split(key, 3)
    gen_p = G.img_generator_init(k_gen, nz=scfg.nz, img_size=scfg.image_size,
                                 out_ch=scfg.in_ch)
    stu_p = student_params if student_params is not None \
        else cnn_init(k_stu, student_spec)

    (gen_step, student_step, g_opt, s_opt, cparams,
     epoch_step) = make_dense_steps(clients, student_spec, scfg,
                                    use_bn=use_bn, use_div=use_div)
    g_state = g_opt.init(gen_p)
    s_state = s_opt.init(stu_p)

    # NB: per-step jit (not the fused epoch_step) — on the 1-core CPU host
    # the fused scan compiles 5x slower and runs 10x slower; on TPU the
    # fused path would win. Kept selectable for completeness.
    hist = DenseHistory()
    s_steps = getattr(scfg, "s_steps", 1)
    for epoch in range(scfg.epochs):
        key, kz, ky = jax.random.split(key, 3)
        z = jax.random.normal(kz, (scfg.synth_batch, scfg.nz))
        y = jax.random.randint(ky, (scfg.synth_batch,), 0, scfg.num_classes)
        for _ in range(scfg.t_g):
            gen_p, g_state, gl, parts = gen_step(gen_p, g_state, stu_p,
                                                 cparams, z, y)
        stu_p, s_state, dl = student_step(stu_p, s_state, gen_p, cparams, z)
        for _ in range(s_steps - 1):
            key, kz2 = jax.random.split(key)
            z2 = jax.random.normal(kz2, (scfg.synth_batch, scfg.nz))
            stu_p, s_state, dl = student_step(stu_p, s_state, gen_p,
                                              cparams, z2)
        hist.gen_loss.append(float(gl))
        hist.gen_parts.append({k: float(v) for k, v in parts.items()})
        hist.dis_loss.append(float(dl))
        if eval_fn is not None and eval_every and (epoch + 1) % eval_every == 0:
            hist.acc.append((epoch + 1, eval_fn(stu_p, student_spec)))
    return stu_p, gen_p, hist


def evaluate(params, spec: CNNSpec, x: np.ndarray, y: np.ndarray,
             batch: int = 512) -> float:
    """Top-1 accuracy, eval-mode BN."""
    correct = 0
    fwd = jax.jit(functools.partial(cnn_logits, spec=spec))
    for i in range(0, len(y), batch):
        logits = fwd(params, x=jnp.asarray(x[i:i + batch]))
        correct += int(jnp.sum(jnp.argmax(logits, -1) == jnp.asarray(y[i:i + batch])))
    return correct / len(y)
