"""DENSE at LLM scale — the paper's server loop as a mesh program.

The paper's setting is CNN classifiers; the technique (average *logits*,
never parameters; synthesize data against the ensemble; distill) is
architecture-agnostic. This module instantiates it for the assigned
decoder-LM families (DESIGN.md §3, §7):

  * clients  = decoder LMs sharing a vocabulary (the label space);
  * generator = token-sequence generator emitting *soft embeddings*
    consumed via ``forward(..., embeds=...)``;
  * D(x̂)    = ensemble-average next-token logits. On the production mesh
    the (homogeneous) client stack is sharded over the ``pod`` axis — one
    client replica group per pod — and the logit average lowers to a
    single cross-pod all-reduce: the paper's server-side python loop
    becomes one collective (DESIGN.md §6);
  * L_BN     = embedding-statistics matching (no BatchNorm exists in these
    LMs; recorded adaptation, DESIGN.md §7.2);
  * L_dis    = token-level KL, fused large-vocab kernel on TPU
    (repro/kernels/distill_kl).
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp

from repro import optim
from repro.configs import backend as B
from repro.configs.base import ArchConfig
from repro.core import generator as G
from repro.core import losses as LS
from repro.models import transformer as T


# --------------------------------------------------- heterogeneous (host) --

def group_lm_clients(client_cfgs: Sequence[ArchConfig]):
    """Group clients by ArchConfig (insertion-ordered, deterministic) —
    the LM analogue of ensemble.group_clients."""
    groups: dict[ArchConfig, list[int]] = {}
    for i, cfg in enumerate(client_cfgs):
        groups.setdefault(cfg, []).append(i)
    return [(cfg, tuple(idx)) for cfg, idx in groups.items()]


def ensemble_lm_logits(client_cfgs: Sequence[ArchConfig], client_params,
                       embeds, *, mesh=None, dp_axes=()):
    """D(x̂) over heterogeneous LM clients (shared vocab).

    Grouped-vmap fast path: identical ArchConfigs are stacked and
    evaluated with one vmapped forward (stacking happens under jit — the
    frozen-CNN path stacks at setup instead, see ensemble.stack_grouped);
    singleton groups run the direct forward."""
    acc = None
    for cfg, idx in group_lm_clients(client_cfgs):
        if len(idx) == 1:
            lg, _, _ = T.forward(client_params[idx[0]], cfg, embeds=embeds,
                                 mesh=mesh, dp_axes=dp_axes, remat=False)
            group_sum = lg.astype(jnp.float32)
        else:
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs),
                                   *[client_params[i] for i in idx])

            def one(p, _cfg=cfg):
                lg_k, _, _ = T.forward(p, _cfg, embeds=embeds, mesh=mesh,
                                       dp_axes=dp_axes, remat=False)
                return lg_k.astype(jnp.float32)

            group_sum = jnp.sum(jax.vmap(one)(stacked), axis=0)
        acc = group_sum if acc is None else acc + group_sum
    return acc / len(client_cfgs)


def embed_stats_loss(client_cfgs, client_params, embeds):
    """L_BN analogue: match generator-output feature statistics to each
    client's embedding-table statistics (computable from the uploaded
    parameters alone — data-free)."""
    mu_g = jnp.mean(embeds.astype(jnp.float32), axis=(0, 1))
    var_g = jnp.var(embeds.astype(jnp.float32), axis=(0, 1))
    total = jnp.zeros((), jnp.float32)
    for cfg, params in zip(client_cfgs, client_params):
        tbl = params["embed"]["table"].astype(jnp.float32)
        total = total + jnp.linalg.norm(mu_g - jnp.mean(tbl, 0)) \
            + jnp.linalg.norm(var_g - jnp.var(tbl, 0))
    return total / len(client_cfgs)


def _reject_autodiff_mode(kernel_vjp_mode: str) -> None:
    """Both step builders differentiate through the trunk, and jax cannot
    differentiate the bare forward kernels (the pallas_call JVP rule
    rejects ``pl.program_id`` bodies) — fail at build time with a real
    message instead of deep inside grad tracing. "autodiff" remains valid
    only for forward-only callers of kernels/ops.py (serving)."""
    if kernel_vjp_mode == "autodiff":
        raise ValueError(
            "kernel_vjp_mode='autodiff' cannot train: jax cannot "
            "differentiate through the forward Pallas kernels — use "
            "'ref' or 'fused' (DESIGN.md §9)")


def make_llm_dense_steps(student_cfg: ArchConfig,
                         client_cfgs: Sequence[ArchConfig], *,
                         gen_seq: int = 64, nz: int = 64,
                         g_lr: float = 1e-3, s_lr: float = 1e-4,
                         lambda_bn: float = 1.0, lambda_div: float = 0.5,
                         mesh=None, dp_axes=(),
                         distill_kl_mode: str | None = None,
                         kernel_vjp_mode: str | None = None,
                         policy=None):
    """Jitted (gen_step, student_step) for a heterogeneous LM federation
    (host/smoke scale; the pod-sharded path is make_pod_distill_step).

    Both modes default to the backend execution-policy registry
    (``policy``, or ``configs.backend.resolve_exec_policy(None)`` —
    DESIGN.md §11); explicit arguments pin them.

    distill_kl_mode: "ref" or "fused" — both L_dis and L_div route
    through losses.softmax_kl, so "fused" streams the (tokens, V) KL and
    its gradients through the Pallas kernel pair (DESIGN.md §9).

    kernel_vjp_mode: "ref", "autodiff" or "fused" — routes every client's
    and the student's attention/SSM layers through kernels/ops.py (the
    same §9 pattern, two more pairs): "fused" differentiates the trunk
    through the streaming custom-VJP kernels — the student backward in
    student_step AND the generator gradients that flow through the
    client/student forwards in gen_step."""
    from repro.kernels import ops as kops
    pol = B.resolve_exec_policy(policy)
    distill_kl_mode = pol.distill_kl if distill_kl_mode is None \
        else distill_kl_mode
    kernel_vjp_mode = pol.kernel_vjp if kernel_vjp_mode is None \
        else kernel_vjp_mode
    LS.check_mode(distill_kl_mode)
    kops.check_kernel_vjp_mode(kernel_vjp_mode)
    _reject_autodiff_mode(kernel_vjp_mode)
    student_cfg = student_cfg.replace(kernel_vjp_mode=kernel_vjp_mode)
    client_cfgs = [c.replace(kernel_vjp_mode=kernel_vjp_mode)
                   for c in client_cfgs]
    g_opt = optim.adam(g_lr)
    s_opt = optim.adam(s_lr)
    V = student_cfg.vocab_size

    @jax.jit
    def gen_step(gen_p, g_state, stu_p, cparams, z, y):
        def loss_fn(gp):
            embeds = G.tok_generator(gp, z, y[:, 0])
            avg = ensemble_lm_logits(client_cfgs, cparams, embeds,
                                     mesh=mesh, dp_axes=dp_axes)
            stu, _, _ = T.forward(stu_p, student_cfg, embeds=embeds,
                                  mesh=mesh, dp_axes=dp_axes, remat=False)
            af = avg.reshape(-1, V)
            sf = stu.astype(jnp.float32).reshape(-1, V)
            l_ce = LS.ce_loss(af, y.reshape(-1))
            l_bn = embed_stats_loss(client_cfgs, cparams, embeds)
            l_div = LS.div_loss(af, sf, mode=distill_kl_mode,
                                 policy=pol)
            return l_ce + lambda_bn * l_bn + lambda_div * l_div, \
                {"ce": l_ce, "bn": l_bn, "div": l_div}

        (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(gen_p)
        new_p, new_s = g_opt.update(grads, g_state, gen_p)
        return new_p, new_s, loss, parts

    @jax.jit
    def student_step(stu_p, s_state, gen_p, cparams, z, y):
        embeds = jax.lax.stop_gradient(G.tok_generator(gen_p, z, y[:, 0]))
        avg = ensemble_lm_logits(client_cfgs, cparams, embeds,
                                 mesh=mesh, dp_axes=dp_axes)

        def loss_fn(sp):
            stu, _, _ = T.forward(sp, student_cfg, embeds=embeds, mesh=mesh,
                                  dp_axes=dp_axes, remat=False)
            return LS.distill_loss(avg.reshape(-1, V),
                                   stu.astype(jnp.float32).reshape(-1, V),
                                   mode=distill_kl_mode,
                                   with_teacher_grad=False, policy=pol)

        loss, grads = jax.value_and_grad(loss_fn)(stu_p)
        new_p, new_s = s_opt.update(grads, s_state, stu_p)
        return new_p, new_s, loss

    return gen_step, student_step, g_opt, s_opt


# ------------------------------------------------ pod-sharded (dry-runable)

def pod_stack_specs(param_specs_tree, mesh):
    """Ensemble-dim sharding for the stacked client params — the pod-mesh
    instance of the shared stacked-client-axis vocabulary
    (``fl.sharding.stack_specs``; the host CNN path spells the same axis
    "clients"). The leading client dim shards over ``pod`` when the mesh
    has one (multi-pod) and stays replicated on a single pod, prepended
    to the per-client Megatron specs (launch/shardings.param_specs)."""
    from repro.fl.sharding import stack_specs
    axis = "pod" if "pod" in mesh.axis_names else None
    return stack_specs(param_specs_tree, axis)


def make_pod_distill_step(cfg: ArchConfig, mesh, *, n_clients: int,
                          s_lr: float = 1e-4, chunked_kl: bool = False,
                          kl_chunk: int = 64,
                          distill_kl_mode: str | None = None,
                          kernel_vjp_mode: str | None = None,
                          policy=None):
    """The paper-representative production cell: DENSE stage-2 distillation
    with a homogeneous client stack vmapped over a leading ensemble dim.

    The caller shards that dim over the ``pod`` mesh axis (multi-pod) —
    the logit mean then lowers to one cross-pod all-reduce — or over no
    axis (single pod: clients resident per-device group, mean is local).
    Batch shards over ``data`` only; student params are pod-replicated, so
    student grads all-reduce across pods exactly like data parallelism.

    chunked_kl (§Perf-4, beyond-paper): never materialize the (B,S,V)
    teacher/student logit tensors — keep trunk outputs as hidden states and
    fuse readout + KL per sequence chunk (the XLA-level analogue of the
    Pallas distill_kl kernel).

    distill_kl_mode routes the materialized path's KL + backward through
    the Pallas custom-VJP kernel pair ("fused", DESIGN.md §9) instead of
    jnp autodiff ("ref"). Orthogonal to chunked_kl, which avoids the
    logit tensors altogether and keeps its internal ref-mode KL.

    kernel_vjp_mode routes the trunk's attention/SSM layers through the
    same §9 pattern (kernels/ops.py): "fused" differentiates the
    student's blocks through the streaming custom-VJP kernel pairs —
    at LLM scale this removes the O(S²) softmax / per-chunk state
    rematerialization that backprop through the XLA forward keeps alive.

    Both modes default to the backend execution-policy registry
    (``policy``, DESIGN.md §11); explicit arguments pin them.
    """
    from repro.kernels import ops as kops
    pol = B.resolve_exec_policy(policy)
    distill_kl_mode = pol.distill_kl if distill_kl_mode is None \
        else distill_kl_mode
    kernel_vjp_mode = pol.kernel_vjp if kernel_vjp_mode is None \
        else kernel_vjp_mode
    LS.check_mode(distill_kl_mode)
    kops.check_kernel_vjp_mode(kernel_vjp_mode)
    _reject_autodiff_mode(kernel_vjp_mode)
    cfg = cfg.replace(kernel_vjp_mode=kernel_vjp_mode)
    s_opt = optim.adam(s_lr)
    dp = tuple(a for a in ("data",) if a in mesh.axis_names)
    V = cfg.vocab_size

    def ens_fwd(stacked_params, embeds, hidden: bool):
        def one(p):
            out, _, _ = T.forward(p, cfg, embeds=embeds, mesh=mesh,
                                  dp_axes=dp, remat=False,
                                  return_hidden=hidden)
            return out if hidden else out.astype(jnp.float32)
        outs = jax.vmap(one)(stacked_params)
        return outs if hidden else jnp.mean(outs, axis=0)

    def loss_materialized(sp, stacked_client_params, embeds):
        avg = ens_fwd(stacked_client_params, embeds, hidden=False)
        stu, _, _ = T.forward(sp, cfg, embeds=embeds, mesh=mesh,
                              dp_axes=dp, remat=True)
        # grads are taken wrt sp only: the teacher cotangent is dead code
        return LS.distill_loss(avg.reshape(-1, V),
                               stu.astype(jnp.float32).reshape(-1, V),
                               mode=distill_kl_mode,
                               with_teacher_grad=False, policy=pol)

    def loss_chunked(sp, stacked_client_params, embeds):
        th = jax.lax.stop_gradient(
            ens_fwd(stacked_client_params, embeds, hidden=True))  # (n,B,S,D)
        sh, _, _ = T.forward(sp, cfg, embeds=embeds, mesh=mesh,
                             dp_axes=dp, remat=True, return_hidden=True)
        t_tbl = stacked_client_params["embed"]["table"]           # (n,V,D)
        s_tbl = sp["embed"]["table"]
        B, S, D = sh.shape
        nc = S // kl_chunk

        def chunk(args):
            th_c, sh_c = args         # (n,B,c,D), (B,c,D)
            t_lg = jnp.mean(jnp.einsum(
                "nbcd,nvd->nbcv", th_c.astype(jnp.float32),
                t_tbl.astype(jnp.float32)), axis=0)
            s_lg = jnp.einsum("bcd,vd->bcv", sh_c, s_tbl.astype(sh_c.dtype))
            return jnp.sum(LS.softmax_kl(t_lg.reshape(-1, V),
                                         s_lg.astype(jnp.float32)
                                         .reshape(-1, V)))

        th_b = jnp.moveaxis(th.reshape(-1, B, nc, kl_chunk, D), 2, 0)
        sh_b = jnp.moveaxis(sh.reshape(B, nc, kl_chunk, D), 1, 0)
        tot = jax.lax.map(chunk, (th_b, sh_b))
        return jnp.sum(tot) / (B * S)

    loss_impl = loss_chunked if chunked_kl else loss_materialized

    def distill_step(stu_state, stacked_client_params, embeds):
        loss, grads = jax.value_and_grad(loss_impl)(
            stu_state["params"], stacked_client_params, embeds)
        new_p, new_opt = s_opt.update(grads, stu_state["opt"],
                                      stu_state["params"])
        return {"params": new_p, "opt": new_opt,
                "step": stu_state["step"] + 1}, {"dis_loss": loss}

    return distill_step


def abstract_pod_inputs(cfg: ArchConfig, *, n_clients: int, batch: int,
                        seq: int):
    """ShapeDtypeStructs for the pod-sharded distillation dry-run."""
    import numpy as np  # noqa: F401
    params = jax.eval_shape(lambda: T.init_model(jax.random.PRNGKey(0), cfg))
    stacked = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n_clients, *s.shape), s.dtype), params)
    opt = jax.eval_shape(lambda: optim.adam(1e-4).init(
        T.init_model(jax.random.PRNGKey(0), cfg)))
    state = {"params": params, "opt": opt,
             "step": jax.ShapeDtypeStruct((), jnp.int32)}
    embeds = jax.ShapeDtypeStruct((batch, seq, cfg.d_model),
                                  jnp.dtype(cfg.dtype))
    return state, stacked, embeds
